//! Cluster sharding — tensor-parallel inference across multiple simulated
//! Quark cores.
//!
//! The paper scales Quark by widening one vector unit (Quark-4L → Quark-8L,
//! Table II); a serving deployment scales by *replicating* it. This module
//! partitions one inference across `N` simulated cores the way SPEED
//! (arXiv 2409.14017) and Sparq argue sub-byte datapaths should be scaled:
//! every Conv/FC layer's output channels are split into `N` contiguous
//! ranges ([`ShardPlan`]), each shard core runs its own relocatable
//! [`CompiledProgram`] (compiled through the same `emit_model` routine as
//! the single-core path — [`crate::program::compile_shard`]), and an
//! activation **all-gather** between layers rebuilds the full feature map on
//! every core:
//!
//! ```text
//!            layer i (sharded)                 sync            layer i+1
//! core 0 ─ conv c_out[0 .. n/N)   ─┐   ┌─ full map ─► conv (full input) …
//! core 1 ─ conv c_out[n/N .. 2n/N) ─┼──►┼─ full map ─► conv (full input) …
//!   …                               │   │  (ring all-gather, N−1 steps
//! core N−1 ─ conv c_out[.. n)     ─┘   └─  charged vs axi_bytes_per_cycle)
//! ```
//!
//! **Bit-exactness.** Shard emission draws synthetic weights/requant
//! parameters from the *full* deterministic stream and column-slices them,
//! so channel `c`'s integer accumulation and scalar-FP requant are the same
//! arithmetic on every topology; the gather is a pure channel permutation of
//! u8 codes (it never re-quantizes, so the bit-plane re-pack rule —
//! narrowest-consumer grids — survives). Gathered logits are therefore
//! bit-identical to the single-core program and to the naive-i128 host
//! golden model (`rust/tests/cluster.rs` holds the differentials).
//!
//! **Cost model.** Per layer, the cluster charges
//! `max(shard cycles) + sync_cost(layer)`, where [`sync_cost`] models the
//! ring all-gather: `N−1` steps, each moving the widest shard's partial
//! slice over the core's AXI link (`axi_bytes_per_cycle`) plus a
//! `mem_latency` start-up. At `N = 1` every layer is unpartitioned, the
//! shard program is emission-identical to [`crate::program::compile`]'s,
//! and the reported cycles equal the single-core cycles exactly.
//!
//! **Host execution.** [`ClusterCores::infer`] replays the shard programs
//! on parallel host threads (one persistent [`Sim`] per shard core),
//! rendezvousing at a [`Barrier`] after each sharded layer to exchange
//! partial maps. [`cluster_timing`] replays them `TimingOnly` (fresh cores,
//! also in parallel) and aggregates the cycle model.

pub mod pipeline;

pub use pipeline::{
    compile_pipeline, hop_cost, pipeline_timing, stage_costs, PipelineCores, PipelineInference,
    PipelineProgram, PipelineTiming, StageTiming,
};

use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::arch::MachineConfig;
use crate::nn::model::{PrecisionMap, ShardPlan};
use crate::nn::NetGraph;
use crate::program::{compile_shard, CompiledProgram, ShardSeg};
use crate::sim::{Sim, SimMode};

/// How a multi-core deployment splits one model across its cores — the
/// scheduling seam future strategies (e.g. Sparq-style sparse kernels) slot
/// into.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ClusterMode {
    /// Tensor parallelism: every core works the same layer on a contiguous
    /// output-channel range, all-gathering activations per layer
    /// ([`ShardPlan`], this module). Minimizes single-request latency.
    #[default]
    Tensor,
    /// Pipeline parallelism: each core owns a contiguous layer range and
    /// activations stream between stages
    /// ([`crate::nn::model::StagePlan`], [`pipeline`]). Maximizes sustained
    /// throughput on deep uniform stacks.
    Pipeline,
}

impl ClusterMode {
    /// Wire label (the `mode=` request field).
    pub fn label(&self) -> &'static str {
        match self {
            ClusterMode::Tensor => "tensor",
            ClusterMode::Pipeline => "pipeline",
        }
    }

    /// Parse a [`ClusterMode::label`]-format string.
    ///
    /// ```
    /// use quark::cluster::ClusterMode;
    /// assert_eq!(ClusterMode::parse("tensor"), Ok(ClusterMode::Tensor));
    /// assert_eq!(ClusterMode::parse("pipeline"), Ok(ClusterMode::Pipeline));
    /// assert!(ClusterMode::parse("ring").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ClusterMode, String> {
        match s {
            "tensor" => Ok(ClusterMode::Tensor),
            "pipeline" => Ok(ClusterMode::Pipeline),
            _ => Err(format!("unknown cluster mode {s:?} (want tensor or pipeline)")),
        }
    }
}

/// A compiled tensor-parallel deployment: one [`CompiledProgram`] per shard
/// core, all over the same (net, machine, schedule).
pub struct ClusterProgram {
    shards: Vec<Arc<CompiledProgram>>,
}

impl ClusterProgram {
    /// Assemble from per-shard programs (e.g. the coordinator's per-shard
    /// cache entries). Programs must be a complete, consistent shard set.
    pub fn from_shards(shards: Vec<Arc<CompiledProgram>>) -> Result<ClusterProgram, String> {
        if shards.is_empty() {
            return Err("a cluster needs at least one shard program".to_string());
        }
        let n = shards.len();
        for (i, p) in shards.iter().enumerate() {
            let (idx, count) = p
                .shard()
                .ok_or_else(|| format!("program {i} is not a shard program"))?;
            if idx != i || count != n {
                return Err(format!(
                    "program {i} is shard {idx}/{count}, expected {i}/{n}"
                ));
            }
            if p.net_fingerprint() != shards[0].net_fingerprint()
                || p.machine_fingerprint() != shards[0].machine_fingerprint()
                || p.schedule() != shards[0].schedule()
            {
                return Err(format!("program {i} belongs to a different deployment"));
            }
        }
        Ok(ClusterProgram { shards })
    }

    /// Number of shard cores.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard programs, in shard order.
    pub fn shard_programs(&self) -> &[Arc<CompiledProgram>] {
        &self.shards
    }

    /// Element count of the final (gathered) feature map.
    pub fn out_elems(&self) -> usize {
        self.shards[0].out_elems()
    }

    /// The schedule the cluster was compiled under.
    pub fn schedule(&self) -> &PrecisionMap {
        self.shards[0].schedule()
    }
}

/// Compile `net` for `machine` under `schedule`, partitioned across
/// `shards` cores. Validates the schedule (like [`crate::program::compile`])
/// plus the shard plan (channel counts, integer-only schedules). Shard
/// programs are independent, so they compile on parallel host threads —
/// cold wall-clock stays near one single-core compile. (The trade is
/// transient memory: each in-flight `ProgramBuilder` owns its own recording
/// arena.)
pub fn compile_cluster(
    net: &NetGraph,
    machine: &MachineConfig,
    schedule: &PrecisionMap,
    shards: usize,
) -> Result<ClusterProgram, String> {
    let plan = ShardPlan::derive(net, shards)?;
    plan.validate_schedule(schedule)?;
    let progs = std::thread::scope(|s| {
        let handles: Vec<_> = (0..shards)
            .map(|i| {
                let plan = &plan;
                s.spawn(move || compile_shard(net, machine, schedule, plan, i).map(Arc::new))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard compile thread panicked"))
            .collect::<Result<Vec<_>, _>>()
    })?;
    ClusterProgram::from_shards(progs)
}

/// Modeled cycles of the ring all-gather after one sharded layer: `N − 1`
/// steps, each moving the widest shard's partial slice (`max_part_bytes`)
/// over the per-core AXI link at `axi_bytes_per_cycle`, with a `mem_latency`
/// start-up per step. 0 for replicated layers and 1-shard clusters.
pub fn sync_cost(cfg: &MachineConfig, shards: usize, max_part_bytes: u64) -> u64 {
    if shards <= 1 || max_part_bytes == 0 {
        return 0;
    }
    let per_step = max_part_bytes.div_ceil(cfg.axi_bytes_per_cycle as u64) + cfg.mem_latency;
    (shards as u64 - 1) * per_step
}

/// One layer of the aggregated cluster cycle model.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub name: String,
    /// `max` over shard cores of the layer's compute cycles.
    pub compute_cycles: u64,
    /// Modeled all-gather cycles after the layer ([`sync_cost`]).
    pub sync_cycles: u64,
}

/// The cluster cycle model: per-layer `max(shard cycles) + sync`, plus the
/// per-core busy totals the utilization numbers derive from.
#[derive(Clone, Debug)]
pub struct ClusterTiming {
    pub layers: Vec<LayerTiming>,
    /// Total compute cycles each shard core spent (Σ of its layer cycles).
    pub shard_cycles: Vec<u64>,
    /// Σ per-layer `max` over shards.
    pub compute_cycles: u64,
    /// Σ per-layer sync.
    pub sync_cycles: u64,
}

impl ClusterTiming {
    /// Modeled end-to-end latency in cycles: compute critical path + sync.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.sync_cycles
    }

    /// Amdahl-style fraction of the modeled latency spent in inter-core
    /// synchronization.
    pub fn sync_fraction(&self) -> f64 {
        self.sync_cycles as f64 / self.total_cycles().max(1) as f64
    }

    /// Modeled utilization of each shard core: its busy cycles over the
    /// cluster's compute critical path (1.0 = never waiting on peers).
    pub fn shard_utilization(&self) -> Vec<f64> {
        self.shard_cycles
            .iter()
            .map(|&c| c as f64 / self.compute_cycles.max(1) as f64)
            .collect()
    }
}

/// Simulated-memory arena for one shard core: the program's footprint plus
/// slack for the replay-base allocation, floored so small programs don't
/// thrash reallocation.
pub(crate) fn shard_mem_bytes(prog: &CompiledProgram) -> usize {
    ((prog.mem_len() as usize) + (1 << 20)).max(16 << 20)
}

/// Derive the cluster cycle model for `cluster`: one `TimingOnly` replay per
/// shard program on parallel host threads (fresh cores — this is the
/// cache-miss path, run once per deployment), aggregated per layer as
/// `max(shard cycles) + sync_cost`.
pub fn cluster_timing(cluster: &ClusterProgram, machine: &MachineConfig) -> ClusterTiming {
    let per_shard: Vec<Vec<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = cluster
            .shards
            .iter()
            .map(|prog| {
                s.spawn(move || {
                    let mut sim = Sim::with_memory(machine.clone(), shard_mem_bytes(prog));
                    sim.set_mode(SimMode::TimingOnly);
                    let base = sim.alloc(prog.mem_len());
                    let run = sim.execute(prog, base);
                    run.reports.iter().map(|r| r.run.cycles).collect::<Vec<u64>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard timing thread panicked")).collect()
    });
    aggregate_timing(cluster, machine, &per_shard)
}

/// Fold per-shard per-layer cycles into the cluster model. Shared with the
/// cycle attributor ([`crate::obs::profile::profile_cluster`]), whose
/// aggregated timeline must equal this one exactly.
pub(crate) fn aggregate_timing(
    cluster: &ClusterProgram,
    machine: &MachineConfig,
    per_shard: &[Vec<u64>],
) -> ClusterTiming {
    let n = cluster.shards();
    let nlayers = cluster.shards[0].layers().len();
    let mut layers = Vec::with_capacity(nlayers);
    let mut shard_cycles = vec![0u64; n];
    for li in 0..nlayers {
        let mut compute = 0u64;
        let mut max_part_bytes = 0u64;
        for (k, cycles) in per_shard.iter().enumerate() {
            compute = compute.max(cycles[li]);
            shard_cycles[k] += cycles[li];
            let seg = &cluster.shards[k].shard_segs()[li];
            if seg.channels.is_some() {
                max_part_bytes = max_part_bytes.max(seg.part_elems() as u64);
            }
        }
        layers.push(LayerTiming {
            name: cluster.shards[0].layers()[li].name.clone(),
            compute_cycles: compute,
            sync_cycles: sync_cost(machine, n, max_part_bytes),
        });
    }
    ClusterTiming {
        compute_cycles: layers.iter().map(|l| l.compute_cycles).sum(),
        sync_cycles: layers.iter().map(|l| l.sync_cycles).sum(),
        layers,
        shard_cycles,
    }
}

/// Result of one functional cluster inference.
pub struct ClusterInference {
    /// The gathered final feature map (u8 logits codes; cluster schedules
    /// are integer-only).
    pub logits: Vec<u8>,
    /// Host wall-clock nanoseconds each shard core spent inside the replay
    /// (incl. barrier waits) — the serving layer's shard-utilization feed.
    pub shard_busy_ns: Vec<u64>,
}

struct ShardCore {
    sim: Sim,
    heap: u64,
}

/// A pool of persistent shard cores (one [`Sim`] each, bump allocator
/// rewound between inferences — the cluster analogue of the coordinator's
/// `WorkerCore`).
pub struct ClusterCores {
    machine: MachineConfig,
    cores: Vec<ShardCore>,
}

impl ClusterCores {
    /// `count` persistent cores for `machine`. Arenas start minimal and grow
    /// to fit the first program replayed on them.
    pub fn new(machine: &MachineConfig, count: usize) -> Self {
        assert!(count >= 1, "a cluster needs at least one core");
        let cores = (0..count)
            .map(|_| {
                let sim = Sim::with_memory(machine.clone(), 16 << 20);
                let heap = sim.machine.mem.brk();
                ShardCore { sim, heap }
            })
            .collect();
        ClusterCores { machine: machine.clone(), cores }
    }

    pub fn count(&self) -> usize {
        self.cores.len()
    }

    /// Functional tensor-parallel inference: replay every shard program on
    /// its own host thread, all-gathering partial activations at each
    /// sharded layer boundary, and return the gathered logits. Memory
    /// effects are bit-identical to a single-core
    /// [`Sim::execute_functional`] of the unsharded program.
    ///
    /// Replay preconditions (shard count, machine identity, arena fit) are
    /// checked *here*, on the caller's thread, before any shard thread
    /// launches: a panic inside a shard thread would strand its peers on
    /// the [`Barrier`] (std barriers do not poison), so the known failure
    /// modes must fire loudly up front instead.
    pub fn infer(&mut self, cluster: &ClusterProgram, input: &[u8]) -> ClusterInference {
        let n = self.cores.len();
        assert_eq!(
            cluster.shards(),
            n,
            "cluster program has {} shards but this pool has {n} cores",
            cluster.shards()
        );
        for (core, prog) in self.cores.iter_mut().zip(cluster.shards.iter()) {
            assert_eq!(
                crate::program::machine_fingerprint(&core.sim.cfg),
                prog.machine_fingerprint(),
                "shard program compiled for a different machine than this pool"
            );
            // Grow any core whose arena can't fit its shard program.
            let need = shard_mem_bytes(prog);
            if core.sim.machine.mem.size() < need {
                core.sim = Sim::with_memory(self.machine.clone(), need);
                core.heap = core.sim.machine.mem.brk();
            }
        }
        // Per-layer channel ranges of every shard (for local reassembly).
        let nlayers = cluster.shards[0].layers().len();
        let ranges: Vec<Vec<Option<(usize, usize)>>> = (0..nlayers)
            .map(|li| cluster.shards.iter().map(|p| p.shard_segs()[li].channels).collect())
            .collect();
        let barrier = Barrier::new(n);
        let slots: Vec<Mutex<Vec<u8>>> = (0..n).map(|_| Mutex::new(Vec::new())).collect();

        let results: Vec<(Vec<u8>, u64)> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .cores
                .iter_mut()
                .zip(cluster.shards.iter())
                .enumerate()
                .map(|(k, (core, prog))| {
                    let (barrier, slots, ranges) = (&barrier, &slots, &ranges);
                    s.spawn(move || {
                        let t0 = Instant::now();
                        core.sim.machine.mem.reset_alloc_to(core.heap);
                        let base = core.sim.alloc(prog.mem_len());
                        let delta = core.sim.begin_replay(prog, base, Some(input));
                        let mut lo = 0usize;
                        for li in 0..nlayers {
                            let seg = &prog.shard_segs()[li];
                            fill_res_slice(&mut core.sim, prog, seg, delta);
                            let hi = layer_trace_end(prog, li);
                            core.sim.execute_functional_range(prog, delta, lo, hi);
                            lo = hi;
                            if n > 1 && seg.channels.is_some() {
                                all_gather(
                                    &mut core.sim,
                                    seg,
                                    delta,
                                    k,
                                    slots,
                                    &ranges[li],
                                    barrier,
                                );
                            }
                        }
                        // Every core holds the gathered logits; core 0
                        // reports them.
                        let logits = if k == 0 {
                            let last = prog.shard_segs().last().expect("non-empty net");
                            core.sim.read_u8s(
                                last.gather_addr.wrapping_add(delta),
                                last.gather_elems(),
                            )
                        } else {
                            Vec::new()
                        };
                        (logits, t0.elapsed().as_nanos() as u64)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard replay thread panicked"))
                .collect()
        });
        let shard_busy_ns = results.iter().map(|(_, ns)| *ns).collect();
        let logits = results.into_iter().next().expect("at least one shard").0;
        ClusterInference { logits, shard_busy_ns }
    }
}

/// Exclusive trace end of layer `li` (its range starts at the previous
/// layer's end).
fn layer_trace_end(prog: &CompiledProgram, li: usize) -> usize {
    prog.layers()[li].trace_end
}

/// Fill a sharded residual layer's slice buffer with this shard's channel
/// range of the (already gathered) residual source map — a local copy, no
/// inter-core traffic: the source bytes were broadcast by its own gather.
fn fill_res_slice(sim: &mut Sim, prog: &CompiledProgram, seg: &ShardSeg, delta: u64) {
    let Some((src_map, slice_addr)) = seg.res_slice else { return };
    let (c0, c1) = seg.channels.expect("res_slice implies a sharded layer");
    let src_addr = if src_map == 0 {
        prog.input.addr
    } else {
        prog.shard_segs()[src_map - 1].gather_addr
    }
    .wrapping_add(delta);
    let full = sim.read_u8s(src_addr, seg.positions * seg.c_full);
    let w = c1 - c0;
    let mut slice = vec![0u8; seg.positions * w];
    for pos in 0..seg.positions {
        slice[pos * w..(pos + 1) * w]
            .copy_from_slice(&full[pos * seg.c_full + c0..pos * seg.c_full + c1]);
    }
    sim.write_bytes(slice_addr.wrapping_add(delta), &slice);
}

/// The host-side all-gather: deposit this shard's partial slice, rendezvous,
/// reassemble the full channel-interleaved map locally, rendezvous again
/// (so no peer's slot is overwritten by the next layer before everyone has
/// read it).
fn all_gather(
    sim: &mut Sim,
    seg: &ShardSeg,
    delta: u64,
    k: usize,
    slots: &[Mutex<Vec<u8>>],
    ranges: &[Option<(usize, usize)>],
    barrier: &Barrier,
) {
    let part = sim.read_u8s(seg.part_addr.wrapping_add(delta), seg.part_elems());
    *slots[k].lock().unwrap() = part;
    barrier.wait();
    let mut full = vec![0u8; seg.gather_elems()];
    for (j, slot) in slots.iter().enumerate() {
        let (s0, s1) = ranges[j].expect("peers shard the same layers");
        let w = s1 - s0;
        let p = slot.lock().unwrap();
        for pos in 0..seg.positions {
            full[pos * seg.c_full + s0..pos * seg.c_full + s1]
                .copy_from_slice(&p[pos * w..(pos + 1) * w]);
        }
    }
    sim.write_bytes(seg.gather_addr.wrapping_add(delta), &full);
    barrier.wait();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::demo_net;
    use crate::nn::model::Precision;

    const W2A2: Precision = Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true };

    #[test]
    fn compile_cluster_validates() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        assert!(compile_cluster(&net, &quark, &PrecisionMap::uniform(W2A2), 0).is_err());
        // demo net's narrowest layer (stem/c1) has 64 channels.
        assert!(compile_cluster(&net, &quark, &PrecisionMap::uniform(W2A2), 128).is_err());
        let cluster = compile_cluster(&net, &quark, &PrecisionMap::uniform(W2A2), 2).unwrap();
        assert_eq!(cluster.shards(), 2);
        for (i, p) in cluster.shard_programs().iter().enumerate() {
            assert_eq!(p.shard(), Some((i, 2)));
            assert_eq!(p.shard_segs().len(), net.len());
        }
        // fp32 cannot shard, even on a machine that could run it.
        assert!(
            compile_cluster(&net, &MachineConfig::ara(4), &PrecisionMap::uniform(Precision::Fp32), 2)
                .is_err()
        );
    }

    #[test]
    fn sync_cost_model_shape() {
        let q = MachineConfig::quark(4); // 32 B/cycle AXI, 20-cycle latency
        assert_eq!(sync_cost(&q, 1, 1 << 20), 0, "one core needs no gather");
        assert_eq!(sync_cost(&q, 4, 0), 0, "replicated layers exchange nothing");
        // 4 shards, 1 KiB widest slice: 3 steps × (1024/32 + 20).
        assert_eq!(sync_cost(&q, 4, 1024), 3 * (32 + 20));
        // More shards move smaller slices but take more steps.
        assert!(sync_cost(&q, 8, 512) > sync_cost(&q, 2, 2048));
    }

    #[test]
    fn from_shards_rejects_mismatched_sets() {
        let net = demo_net();
        let quark = MachineConfig::quark(4);
        let sched = PrecisionMap::uniform(W2A2);
        let c2 = compile_cluster(&net, &quark, &sched, 2).unwrap();
        // Wrong order.
        let mut progs = c2.shard_programs().to_vec();
        progs.swap(0, 1);
        assert!(ClusterProgram::from_shards(progs).is_err());
        // Incomplete set.
        assert!(ClusterProgram::from_shards(c2.shard_programs()[..1].to_vec()).is_err());
        // Non-shard program.
        let single = Arc::new(crate::program::compile(&net, &quark, &sched).unwrap());
        assert!(ClusterProgram::from_shards(vec![single]).is_err());
    }
}
