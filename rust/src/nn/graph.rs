//! [`NetGraph`] — first-class model identity.
//!
//! Historically "the workload" was a bare `Vec<NetLayer>` threaded through
//! every consumer (runner, compiler, golden model, serving layer, reports),
//! with an ad-hoc structural hash (`net_fingerprint`) re-derived wherever a
//! cache key was needed. `NetGraph` replaces that with a validated,
//! self-identifying value:
//!
//! * **name** — the registry identity (`resnet18-cifar@100`, `tiny@100`,
//!   …; see [`crate::nn::zoo`]). The serving layer keys deployments and
//!   wire requests (`net=`) by it.
//! * **num_classes** — the classifier width, checked against the final FC
//!   layer when one is present (truncated `--fast` graphs end mid-network
//!   and skip the check).
//! * **construction-time validation** — every feature-map index must point
//!   backwards, every layer's input shape must match its producer's output
//!   shape (layers reading map 0 read a prefix of the fixed
//!   [`INPUT_ELEMS`]-byte input plane), and residual wiring must be
//!   shape-consistent. A `Vec<NetLayer>` that would make the emitter read
//!   out of bounds can no longer reach it.
//! * **[`NetGraph::fingerprint`]** — the cache identity, computed once at
//!   construction: the structural hash of the layer list
//!   ([`structural_fingerprint`], the former `net_fingerprint`) folded with
//!   the name and class count. Two models that share a topology but not a
//!   name are distinct deployments.

use crate::kernels::Conv2dParams;

use super::resnet::{LayerKind, NetLayer};

/// Logical element count of feature map 0 — the fixed CIFAR-sized
/// (32·32·3) byte plane every model reads its input from. Models with a
/// smaller input read a prefix; the serving layer rejects longer payloads
/// ([`crate::coordinator::server::MAX_INPUT_BYTES`]).
pub const INPUT_ELEMS: usize = 32 * 32 * 3;

#[inline]
pub(crate) fn fnv(h: &mut u64, v: u64) {
    // FNV-1a over the 8 bytes of `v`.
    for b in v.to_le_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

pub(crate) fn fnv_str(h: &mut u64, s: &str) {
    fnv(h, s.len() as u64);
    for &b in s.as_bytes() {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

/// Structural identity of a layer list: every field that can change the
/// emitted instruction stream (shapes, layer kinds, wiring) is folded in.
/// This is the hash the coordinator historically called `net_fingerprint`;
/// [`NetGraph::fingerprint`] folds the model name and class count on top.
pub fn structural_fingerprint(net: &[NetLayer]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, net.len() as u64);
    for layer in net {
        fnv(&mut h, layer.input as u64);
        fnv(&mut h, layer.residual_from.map(|i| i as u64 + 1).unwrap_or(0));
        match &layer.kind {
            LayerKind::Conv(c) => {
                fnv(&mut h, 1);
                fnv_str(&mut h, &c.name);
                let p = c.params;
                for v in [p.h, p.w, p.c_in, p.c_out, p.kh, p.kw, p.stride, p.pad] {
                    fnv(&mut h, v as u64);
                }
                fnv(&mut h, c.relu as u64);
                fnv(&mut h, c.residual as u64);
                fnv(&mut h, c.quantized as u64);
            }
            LayerKind::AvgPool { h: ph, w: pw, c } => {
                fnv(&mut h, 2);
                for v in [*ph, *pw, *c] {
                    fnv(&mut h, v as u64);
                }
            }
            LayerKind::Fc { k, n, name } => {
                fnv(&mut h, 3);
                fnv_str(&mut h, name);
                fnv(&mut h, *k as u64);
                fnv(&mut h, *n as u64);
            }
        }
    }
    h
}

/// `(input elems read, output elems produced)` of one layer.
fn layer_shape(kind: &LayerKind) -> (usize, usize) {
    match kind {
        LayerKind::Conv(c) => {
            let p: &Conv2dParams = &c.params;
            (p.h * p.w * p.c_in, p.out_h() * p.out_w() * p.c_out)
        }
        LayerKind::AvgPool { h, w, c } => (h * w * c, *c),
        LayerKind::Fc { k, n, .. } => (*k, *n),
    }
}

/// A validated, named model graph — see the module docs.
///
/// Dereferences to `[NetLayer]`, so graph-walking helpers
/// ([`crate::nn::model::PrecisionMap::validate`],
/// [`crate::nn::model::map_consumer_bits`],
/// [`crate::nn::resnet::quantized_layers`], …) take a `&NetGraph`
/// unchanged.
#[derive(Clone, Debug)]
pub struct NetGraph {
    name: String,
    num_classes: usize,
    layers: Vec<NetLayer>,
    fingerprint: u64,
}

impl NetGraph {
    /// Validate and wrap a layer list. `name` is the wire identity (ascii
    /// alphanumerics plus `@ - _ . #`, no whitespace or commas — it travels
    /// in `net=` fields and `serve --models` lists); `num_classes` must
    /// match the final FC width when the graph ends in a classifier.
    pub fn new(name: &str, num_classes: usize, layers: Vec<NetLayer>) -> Result<NetGraph, String> {
        if name.is_empty() {
            return Err("model name must not be empty".to_string());
        }
        if let Some(c) =
            name.chars().find(|c| !c.is_ascii_alphanumeric() && !"@-_.#".contains(*c))
        {
            return Err(format!(
                "model name {name:?} contains {c:?} (allowed: ascii alphanumerics and @-_.#)"
            ));
        }
        if layers.is_empty() {
            return Err(format!("model {name:?} has no layers"));
        }
        // elems[m] = logical element count of feature map m (map 0 = input;
        // layer i writes map i + 1).
        let mut elems: Vec<usize> = vec![INPUT_ELEMS];
        for (i, layer) in layers.iter().enumerate() {
            let ctx = || format!("model {name:?} layer {i} ({})", layer_label(&layer.kind));
            if layer.input > i {
                return Err(format!(
                    "{}: input map {} does not exist yet (maps 0..={i} are defined)",
                    ctx(),
                    layer.input
                ));
            }
            let (expected, produced) = layer_shape(&layer.kind);
            if layer.input == 0 {
                if expected > INPUT_ELEMS {
                    return Err(format!(
                        "{}: reads {expected} elements from the {INPUT_ELEMS}-element input plane",
                        ctx()
                    ));
                }
            } else if expected != elems[layer.input] {
                return Err(format!(
                    "{}: reads {expected} elements but map {} holds {}",
                    ctx(),
                    layer.input,
                    elems[layer.input]
                ));
            }
            let is_residual_conv = matches!(&layer.kind, LayerKind::Conv(c) if c.residual);
            match (is_residual_conv, layer.residual_from) {
                (true, None) => {
                    return Err(format!("{}: residual conv without a residual_from map", ctx()));
                }
                (false, Some(_)) => {
                    return Err(format!("{}: residual_from on a non-residual layer", ctx()));
                }
                (true, Some(r)) => {
                    if r > i {
                        return Err(format!(
                            "{}: residual map {r} does not exist yet (maps 0..={i})",
                            ctx()
                        ));
                    }
                    if elems[r] != produced {
                        return Err(format!(
                            "{}: residual map {r} holds {} elements, output has {produced}",
                            ctx(),
                            elems[r]
                        ));
                    }
                }
                (false, None) => {}
            }
            elems.push(produced);
        }
        if let Some(NetLayer { kind: LayerKind::Fc { n, .. }, .. }) = layers.last() {
            if *n != num_classes {
                return Err(format!(
                    "model {name:?} declares {num_classes} classes but its classifier has {n} outputs"
                ));
            }
        }
        let mut fingerprint = structural_fingerprint(&layers);
        fnv_str(&mut fingerprint, name);
        fnv(&mut fingerprint, num_classes as u64);
        Ok(NetGraph { name: name.to_string(), num_classes, layers, fingerprint })
    }

    /// The model's wire identity (canonical registry spec for zoo models,
    /// e.g. `resnet18-cifar@100`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Classifier width the graph was declared with. (For truncated
    /// `--fast` graphs the classifier itself may be cut off; the declared
    /// width is kept for display.)
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The layer list, in network order.
    pub fn layers(&self) -> &[NetLayer] {
        &self.layers
    }

    /// Logical element count of the final feature map (the logits, for
    /// classifier graphs).
    pub fn out_elems(&self) -> usize {
        layer_shape(&self.layers.last().expect("graphs are non-empty").kind).1
    }

    /// Stable cache identity: structure ⊕ name ⊕ class count, computed once
    /// at construction. The coordinator's timing/program `DeployKey`s and
    /// every [`crate::program::CompiledProgram`] carry it.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }
}

fn layer_label(kind: &LayerKind) -> String {
    match kind {
        LayerKind::Conv(c) => c.name.clone(),
        LayerKind::AvgPool { .. } => "avgpool".to_string(),
        LayerKind::Fc { name, .. } => name.clone(),
    }
}

impl std::ops::Deref for NetGraph {
    type Target = [NetLayer];

    fn deref(&self) -> &[NetLayer] {
        &self.layers
    }
}

impl AsRef<[NetLayer]> for NetGraph {
    fn as_ref(&self) -> &[NetLayer] {
        &self.layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ConvLayer;

    fn conv(name: &str, h: usize, c_in: usize, c_out: usize, residual: bool) -> ConvLayer {
        ConvLayer {
            name: name.into(),
            params: Conv2dParams {
                h,
                w: h,
                c_in,
                c_out,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            relu: true,
            residual,
            quantized: true,
        }
    }

    fn valid_layers() -> Vec<NetLayer> {
        vec![
            NetLayer {
                kind: LayerKind::Conv(ConvLayer { quantized: false, ..conv("stem", 8, 3, 64, false) }),
                input: 0,
                residual_from: None,
            },
            NetLayer { kind: LayerKind::Conv(conv("c1", 8, 64, 64, false)), input: 1, residual_from: None },
            NetLayer { kind: LayerKind::AvgPool { h: 8, w: 8, c: 64 }, input: 2, residual_from: None },
            NetLayer { kind: LayerKind::Fc { k: 64, n: 10, name: "fc".into() }, input: 3, residual_from: None },
        ]
    }

    #[test]
    fn valid_graph_constructs_with_identity() {
        let g = NetGraph::new("mini@10", 10, valid_layers()).unwrap();
        assert_eq!(g.name(), "mini@10");
        assert_eq!(g.num_classes(), 10);
        assert_eq!(g.len(), 4, "deref exposes the layer list");
        assert_eq!(g.out_elems(), 10);
        assert_eq!(g.fingerprint(), NetGraph::new("mini@10", 10, valid_layers()).unwrap().fingerprint());
    }

    #[test]
    fn fingerprint_separates_structure_name_and_classes() {
        let base = NetGraph::new("mini@10", 10, valid_layers()).unwrap();
        // Same structure, different name: distinct identity.
        let renamed = NetGraph::new("other@10", 10, valid_layers()).unwrap();
        assert_ne!(base.fingerprint(), renamed.fingerprint());
        // Different structure, same name (classifier relabeled).
        let mut layers = valid_layers();
        layers[3] = NetLayer {
            kind: LayerKind::Fc { k: 64, n: 10, name: "fcx".into() },
            input: 3,
            residual_from: None,
        };
        let relabeled = NetGraph::new("mini@10", 10, layers).unwrap();
        assert_ne!(base.fingerprint(), relabeled.fingerprint());
        // The structural part matches the raw-layer hash.
        assert_eq!(
            structural_fingerprint(&base),
            structural_fingerprint(&valid_layers()),
        );
    }

    #[test]
    fn construction_rejects_bad_wiring_and_shapes() {
        // Forward input reference.
        let mut layers = valid_layers();
        layers[1].input = 3;
        assert!(NetGraph::new("bad", 10, layers).is_err());
        // Input shape mismatch against the producer.
        let mut layers = valid_layers();
        layers[1].kind = LayerKind::Conv(conv("c1", 8, 32, 64, false));
        assert!(NetGraph::new("bad", 10, layers).unwrap_err().contains("reads"));
        // Over-reading the shared input plane.
        let layers = vec![NetLayer {
            kind: LayerKind::Conv(conv("c1", 64, 64, 64, false)),
            input: 0,
            residual_from: None,
        }];
        assert!(NetGraph::new("bad", 10, layers).unwrap_err().contains("input plane"));
        // Residual conv without a source, and the converse.
        let mut layers = valid_layers();
        layers[1].kind = LayerKind::Conv(conv("c1", 8, 64, 64, true));
        assert!(NetGraph::new("bad", 10, layers.clone()).unwrap_err().contains("residual"));
        layers[1].kind = LayerKind::Conv(conv("c1", 8, 64, 64, false));
        layers[1].residual_from = Some(0);
        assert!(NetGraph::new("bad", 10, layers).unwrap_err().contains("non-residual"));
        // Residual shape mismatch (map 0 holds 3072 elements, output 4096).
        let mut layers = valid_layers();
        layers[1].kind = LayerKind::Conv(conv("c1", 8, 64, 64, true));
        layers[1].residual_from = Some(0);
        assert!(NetGraph::new("bad", 10, layers).unwrap_err().contains("residual map 0"));
        // Classifier width vs declared classes.
        assert!(NetGraph::new("bad", 100, valid_layers()).unwrap_err().contains("classes"));
        // Names are wire-safe.
        assert!(NetGraph::new("has space", 10, valid_layers()).is_err());
        assert!(NetGraph::new("has,comma", 10, valid_layers()).is_err());
        assert!(NetGraph::new("", 10, valid_layers()).is_err());
        // Empty layer list.
        assert!(NetGraph::new("empty", 10, Vec::new()).is_err());
    }

    #[test]
    fn truncated_headless_graph_skips_the_classifier_check() {
        let mut layers = valid_layers();
        layers.truncate(2); // ends mid-network, no FC
        let g = NetGraph::new("mini@10", 10, layers).unwrap();
        assert_eq!(g.num_classes(), 10, "declared classes survive truncation");
        assert_eq!(g.out_elems(), 8 * 8 * 64);
    }
}
