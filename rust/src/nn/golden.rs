//! Naive-i128 host reference execution of a network under a precision
//! schedule.
//!
//! Independent of the vector-ISA emulation: every accumulation here is a
//! plain i128 loop over the same synthetic parameter streams the simulator
//! writes (the `synth_*` helpers in [`super::model`]), and the only shared
//! arithmetic is the scalar-FP requant mirror
//! [`crate::kernels::requantize::requant_host`] — which the
//! `requant_differential` suite proves equal to a pure-integer
//! shift/round/clamp model. The mixed-precision differential test
//! (`rust/tests/mixed_precision.rs`) compares every layer's feature map from
//! [`run_golden`] bit-for-bit against the simulated run.
//!
//! Semantics mirrored per layer kind:
//!
//! * **int8 conv / FC** — `ACC = Σ a·w` over the zero-padded im2col patch
//!   (u8 codes × signed i8 weights), no ASUM term;
//! * **bit-serial conv / FC** — `ACC = Σ (a mod 2^act_bits)·w` (the kernel
//!   packs only `act_bits` activation planes) plus the `β·ASUM` correction,
//!   where ASUM sums the *full* u8 patch codes (`emit_row_sum_u8`);
//! * **global average pool** — channel sums with `alpha = 1/(h·w)`;
//! * **residuals** — read as full u8 codes by the requant stage (the
//!   synthetic `res_scale` is 0, exactly as the runner configures it);
//! * **re-pack rule** — every layer clamps onto its narrowest consumer's
//!   grid ([`super::model::map_consumer_bits`]).

use crate::kernels::requantize::requant_host;
use crate::nn::graph::{NetGraph, INPUT_ELEMS};
use crate::nn::model::{
    grid_qmax, map_consumer_bits, synth_codes, synth_i8, synth_input, synth_rq_params, Precision,
    PrecisionMap,
};
use crate::nn::LayerKind;

/// Per-feature-map results of a host golden run: `maps[0]` is the (clamped)
/// network input, layer `i`'s output is `maps[i + 1]`.
pub struct GoldenRun {
    pub maps: Vec<Vec<u8>>,
}

fn to_i32(v: i128, what: &str) -> i32 {
    i32::try_from(v).unwrap_or_else(|_| panic!("{what} {v} overflows the i32 accumulator"))
}

/// Execute `net` under `schedule` on the host with naive integer loops.
/// Integer schedules only (the fp32 baseline has its own golden oracles in
/// the kernel tests). Panics on invalid schedules, mirroring
/// [`super::model::ModelRunner::run_scheduled`].
pub fn run_golden(net: &NetGraph, schedule: &PrecisionMap, input: Option<&[u8]>) -> GoldenRun {
    if let Err(e) = schedule.validate(net) {
        panic!("invalid schedule: {e}");
    }
    assert!(
        schedule.default_precision() != Precision::Fp32,
        "integer schedules only"
    );
    let resolved = schedule.resolve(net);
    let bits = map_consumer_bits(net, &resolved);
    let mut seed = 0xC0FFEEu64 ^ schedule.seed_tag();

    // Input map: same draw/override/clamp sequence as the runner.
    let mut codes = synth_input(&mut seed, INPUT_ELEMS);
    if let Some(bytes) = input {
        for (i, c) in codes.iter_mut().enumerate() {
            *c = bytes.get(i).copied().unwrap_or(0);
        }
    }
    let in_qmax = grid_qmax(bits[0]) as u8;
    for c in codes.iter_mut() {
        *c = (*c).min(in_qmax);
    }

    let mut maps: Vec<Vec<u8>> = vec![codes];
    for (li, layer) in net.iter().enumerate() {
        let lp = resolved[li];
        let qmax = grid_qmax(bits[li + 1]) as f32;
        let out: Vec<u8> = match &layer.kind {
            LayerKind::Conv(c) => {
                let p = c.params;
                let (k, n) = (p.k(), p.c_out);
                let (alphas, betas, biases) = synth_rq_params(n, k);
                let (oh, ow) = (p.out_h(), p.out_w());
                let a = &maps[layer.input];
                let res_map = if c.residual {
                    layer.residual_from.map(|i| &maps[i])
                } else {
                    None
                };
                // Weight draw order must mirror the runner exactly.
                let (w_i8, w_codes, amask) = match lp {
                    Precision::Int8 => (synth_i8(&mut seed, k * n), Vec::new(), 0u8),
                    Precision::Sub { abits, wbits, .. } => {
                        (Vec::new(), synth_codes(&mut seed, k * n, wbits), grid_qmax(abits) as u8)
                    }
                    Precision::Fp32 => unreachable!("integer schedules only"),
                };
                let bitserial = matches!(lp, Precision::Sub { .. });
                let mut out = vec![0u8; oh * ow * n];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let taps = p.valid_taps(oy, ox);
                        // ASUM over the full u8 patch codes (padding is 0).
                        let asum = if bitserial {
                            let mut s: i128 = 0;
                            for &(_, _, iy, ix) in &taps {
                                for ci in 0..p.c_in {
                                    s += a[(iy * p.w + ix) * p.c_in + ci] as i128;
                                }
                            }
                            Some(to_i32(s, "ASUM"))
                        } else {
                            None
                        };
                        for ch in 0..n {
                            let mut acc: i128 = 0;
                            for &(dy, dx, iy, ix) in &taps {
                                for ci in 0..p.c_in {
                                    let av = a[(iy * p.w + ix) * p.c_in + ci];
                                    let kk = (dy * p.kw + dx) * p.c_in + ci;
                                    if bitserial {
                                        acc += (av & amask) as i128 * w_codes[kk * n + ch] as i128;
                                    } else {
                                        acc += av as i128 * w_i8[kk * n + ch] as i128;
                                    }
                                }
                            }
                            let res = res_map.map(|m| m[(oy * ow + ox) * n + ch]);
                            out[(oy * ow + ox) * n + ch] = requant_host(
                                to_i32(acc, "ACC"),
                                asum,
                                res,
                                alphas[ch],
                                betas[ch],
                                biases[ch],
                                qmax,
                                0.0,
                            );
                        }
                    }
                }
                out
            }
            LayerKind::AvgPool { h, w, c } => {
                let a = &maps[layer.input];
                let hw = *h * *w;
                let alpha = 1.0 / hw as f32;
                let mut out = vec![0u8; *c];
                for j in 0..*c {
                    let mut sum: i128 = 0;
                    for pos in 0..hw {
                        sum += a[pos * *c + j] as i128;
                    }
                    out[j] = requant_host(to_i32(sum, "pool sum"), None, None, alpha, 0.0, 0.0, qmax, 0.0);
                }
                out
            }
            LayerKind::Fc { k, n, name: _ } => {
                let (k, n) = (*k, *n);
                let a = &maps[layer.input];
                let (alphas, betas, biases) = synth_rq_params(n, k);
                match lp {
                    Precision::Int8 => {
                        let w = synth_i8(&mut seed, k * n);
                        let mut out = vec![0u8; n];
                        for j in 0..n {
                            let mut acc: i128 = 0;
                            for kk in 0..k {
                                acc += a[kk] as i128 * w[kk * n + j] as i128;
                            }
                            out[j] = requant_host(
                                to_i32(acc, "ACC"),
                                None,
                                None,
                                alphas[j],
                                betas[j],
                                biases[j],
                                qmax,
                                0.0,
                            );
                        }
                        out
                    }
                    Precision::Sub { abits, wbits, .. } => {
                        let w = synth_codes(&mut seed, k * n, wbits);
                        let amask = grid_qmax(abits) as u8;
                        let mut asum: i128 = 0;
                        for kk in 0..k {
                            asum += a[kk] as i128;
                        }
                        let asum = to_i32(asum, "ASUM");
                        let mut out = vec![0u8; n];
                        for j in 0..n {
                            let mut acc: i128 = 0;
                            for kk in 0..k {
                                acc += (a[kk] & amask) as i128 * w[kk * n + j] as i128;
                            }
                            out[j] = requant_host(
                                to_i32(acc, "ACC"),
                                Some(asum),
                                None,
                                alphas[j],
                                betas[j],
                                biases[j],
                                qmax,
                                0.0,
                            );
                        }
                        out
                    }
                    Precision::Fp32 => unreachable!("integer schedules only"),
                }
            }
        };
        maps.push(out);
    }
    GoldenRun { maps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::NetLayer;

    #[test]
    fn golden_is_deterministic_and_shaped() {
        // Structure-only smoke test; the bit-exact cross-check against the
        // simulator lives in rust/tests/mixed_precision.rs.
        let conv = |name: &str, c_in: usize, quantized: bool| crate::nn::ConvLayer {
            name: name.into(),
            params: crate::kernels::Conv2dParams {
                h: 8,
                w: 8,
                c_in,
                c_out: 64,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            relu: true,
            residual: false,
            quantized,
        };
        let net = NetGraph::new(
            "golden-mini",
            0,
            vec![
                NetLayer { kind: LayerKind::Conv(conv("stem", 3, false)), input: 0, residual_from: None },
                NetLayer { kind: LayerKind::Conv(conv("c1", 64, true)), input: 1, residual_from: None },
            ],
        )
        .unwrap();
        let sched = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
        let input: Vec<u8> = (0..3072).map(|i| (i % 251) as u8).collect();
        let a = run_golden(&net, &sched, Some(&input));
        let b = run_golden(&net, &sched, Some(&input));
        assert_eq!(a.maps.len(), net.len() + 1);
        for (x, y) in a.maps.iter().zip(b.maps.iter()) {
            assert_eq!(x, y);
        }
        // Stem output feeds a 2-bit consumer: codes must sit on its grid.
        assert!(a.maps[1].iter().all(|&v| v <= 3), "re-pack clamp violated");
    }
}
