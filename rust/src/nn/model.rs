//! Model materialization + execution on a simulated machine.
//!
//! [`ModelRunner::run_resnet18`] is what the Fig. 3 harness, the end-to-end
//! example, and the coordinator all call: it allocates feature maps and
//! weights in simulated memory, emits every layer through the matching
//! kernel for the chosen [`Precision`], and reports per-layer cycles.

use crate::kernels::bitpack::setup_index_vector;
use crate::kernels::conv2d::{bitserial_block, conv2d_bitserial, conv2d_f32, conv2d_int8};
use crate::kernels::matmul::{matmul_bitserial, matmul_f32, matmul_int8};
use crate::kernels::pool::{global_avgpool_f32, global_avgpool_u8};
use crate::kernels::requantize::RqBuf;
use crate::kernels::KernelRun;
use crate::quant::pack_weight_planes;
use crate::sim::{Sim, Stats};

use super::resnet::{LayerKind, NetLayer};

/// Execution precision for a model run.
///
/// `Eq + Hash` so precisions can key the coordinator's timing cache (the
/// enum carries only integers and booleans).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// FP32 baseline (requires the vector FPU — Ara).
    Fp32,
    /// Int8 baseline (integer-only; the paper runs it on Ara).
    Int8,
    /// Sub-byte bit-serial (requires the Quark ISA). `use_vbitpack = false`
    /// selects the pure-RVV packing fallback (Fig. 3 ablation).
    Sub { abits: u8, wbits: u8, use_vbitpack: bool },
}

impl Precision {
    pub fn label(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".into(),
            Precision::Int8 => "int8".into(),
            Precision::Sub { abits, wbits, use_vbitpack } => {
                format!("w{wbits}a{abits}{}", if *use_vbitpack { "" } else { "-novbp" })
            }
        }
    }
}

/// Per-layer result of a model run.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub quantized: bool,
    pub run: KernelRun,
    pub stats: Stats,
}

/// Deterministic pseudo-random generator for synthetic weights/inputs.
pub fn lcg(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *seed >> 33
}

/// Result of a whole-model run: the per-layer reports plus where the final
/// feature map (the logits, for classifier graphs) landed in simulated
/// memory — the serving layer reads real outputs from there.
#[derive(Clone, Debug)]
pub struct ModelRun {
    pub reports: Vec<LayerReport>,
    /// Simulated address of the last layer's output buffer.
    pub out_addr: u64,
    /// Logical element count of the last layer's output (e.g. class count).
    pub out_elems: usize,
}

/// Logical output element count of one layer.
fn layer_out_elems(kind: &LayerKind) -> usize {
    match kind {
        LayerKind::Conv(c) => c.params.out_h() * c.params.out_w() * c.params.c_out,
        LayerKind::AvgPool { c, .. } => *c,
        LayerKind::Fc { n, .. } => *n,
    }
}

pub struct ModelRunner;

impl ModelRunner {
    /// Run a network graph (see [`super::resnet::resnet18_cifar`]) at the
    /// given precision; batch 1, synthetic weights. When `write_data` is
    /// false the simulator should be in `TimingOnly` mode (cycle counts are
    /// identical — the kernels are data-independent).
    pub fn run(
        sim: &mut Sim,
        net: &[NetLayer],
        precision: Precision,
        write_data: bool,
    ) -> Vec<LayerReport> {
        Self::run_with_input(sim, net, precision, write_data, None).reports
    }

    /// Like [`Self::run`], but with an optional explicit network input
    /// (CIFAR-sized u8 codes; shorter inputs are zero-padded, longer ones
    /// truncated). Synthetic weights are drawn from the same deterministic
    /// stream whether or not an input is supplied, so two runs differ only
    /// in the input feature map. Returns the output buffer location so
    /// callers can read real logits after a `Full`-mode run.
    pub fn run_with_input(
        sim: &mut Sim,
        net: &[NetLayer],
        precision: Precision,
        write_data: bool,
        input: Option<&[u8]>,
    ) -> ModelRun {
        match precision {
            Precision::Fp32 => assert!(sim.cfg.has_vfpu, "FP32 model needs Ara"),
            Precision::Sub { abits, wbits, .. } => {
                assert!(sim.cfg.has_quark_isa, "sub-byte model needs Quark");
                assert!(abits <= 2 && wbits <= 2);
            }
            Precision::Int8 => {}
        }
        let esz = if precision == Precision::Fp32 { 4usize } else { 1 };
        let idx_vec = setup_index_vector(sim);
        let mut seed = 0xC0FFEE
            ^ match precision {
                Precision::Fp32 => 1,
                Precision::Int8 => 2,
                Precision::Sub { .. } => 3,
            };

        // Feature-map addresses; map 0 is the network input (32×32×3).
        let input_elems = 32 * 32 * 3;
        let in_addr = sim.alloc((input_elems * esz) as u64);
        if write_data {
            // Draw the synthetic input even when an explicit one overrides it,
            // so the weight streams below are identical either way.
            let mut codes: Vec<u8> =
                (0..input_elems).map(|_| (lcg(&mut seed) % 256) as u8).collect();
            if let Some(bytes) = input {
                for (i, c) in codes.iter_mut().enumerate() {
                    *c = bytes.get(i).copied().unwrap_or(0);
                }
            }
            match precision {
                Precision::Fp32 => {
                    let vals: Vec<f32> = codes.iter().map(|&c| c as f32 / 255.0).collect();
                    sim.write_f32s(in_addr, &vals);
                }
                _ => sim.write_bytes(in_addr, &codes),
            }
        }
        let mut maps: Vec<u64> = vec![in_addr];
        let mut reports = Vec::new();

        for layer in net {
            let input = maps[layer.input];
            let residual = layer.residual_from.map(|i| maps[i]);
            let before = sim.stats().clone();
            let (out_addr, name, run, quantized) = match &layer.kind {
                LayerKind::Conv(c) => {
                    let p = c.params;
                    let out_elems = p.out_h() * p.out_w() * p.c_out;
                    let out = sim.alloc((out_elems * esz) as u64);
                    let k = p.k();
                    let n = p.c_out;
                    let run = match precision {
                        Precision::Fp32 => {
                            let w = sim.alloc((k * n * 4) as u64);
                            let b = sim.alloc((n * 4) as u64);
                            if write_data {
                                let wv: Vec<f32> = (0..k * n)
                                    .map(|_| (lcg(&mut seed) % 200) as f32 / 1000.0 - 0.1)
                                    .collect();
                                sim.write_f32s(w, &wv);
                                sim.write_f32s(b, &vec![0.01; n]);
                            }
                            conv2d_f32(sim, &p, input, w, b, out, c.relu, if c.residual { residual } else { None })
                        }
                        Precision::Int8 | Precision::Sub { .. } if !c.quantized => {
                            // Stem runs int8 under every integer precision.
                            let w = sim.alloc((k * n) as u64);
                            if write_data {
                                let wv: Vec<i8> =
                                    (0..k * n).map(|_| (lcg(&mut seed) % 256) as i8).collect();
                                sim.write_i8(w, &wv);
                            }
                            let rq = Self::rqbuf(sim, n, k, c.relu);
                            conv2d_int8(sim, &p, input, w, &rq, out, None)
                        }
                        Precision::Int8 => {
                            let w = sim.alloc((k * n) as u64);
                            if write_data {
                                let wv: Vec<i8> =
                                    (0..k * n).map(|_| (lcg(&mut seed) % 256) as i8).collect();
                                sim.write_i8(w, &wv);
                            }
                            let rq = Self::rqbuf(sim, n, k, c.relu);
                            conv2d_int8(sim, &p, input, w, &rq, out, if c.residual { residual } else { None })
                        }
                        Precision::Sub { abits, wbits, use_vbitpack } => {
                            let codes: Vec<u8> = if write_data {
                                (0..k * n).map(|_| (lcg(&mut seed) % (1 << wbits)) as u8).collect()
                            } else {
                                vec![0u8; k * n]
                            };
                            let block = bitserial_block(sim.cfg.vlen_bits, n);
                            let wpk = pack_weight_planes(&codes, k, n, wbits, block);
                            let w = sim.alloc(wpk.byte_len() as u64);
                            if write_data {
                                for (i, &word) in wpk.words.iter().enumerate() {
                                    sim.machine.mem.write_u64_le(w + (i * 8) as u64, word, 8);
                                }
                            }
                            let rq = Self::rqbuf(sim, n, k, c.relu);
                            conv2d_bitserial(
                                sim,
                                &p,
                                abits,
                                input,
                                &wpk,
                                w,
                                &rq,
                                out,
                                if c.residual { residual } else { None },
                                use_vbitpack,
                                idx_vec,
                            )
                        }
                    };
                    (out, c.name.clone(), run, c.quantized)
                }
                LayerKind::AvgPool { h, w, c } => {
                    let out = sim.alloc((c * esz) as u64);
                    let run = match precision {
                        Precision::Fp32 => global_avgpool_f32(sim, *h, *w, *c, input, out),
                        _ => {
                            let alpha = 1.0 / (*h * *w) as f32;
                            let rq = RqBuf::create(
                                sim,
                                &vec![alpha; *c],
                                &vec![0.0; *c],
                                &vec![0.0; *c],
                                255.0,
                                0.0,
                            );
                            global_avgpool_u8(sim, *h, *w, *c, input, &rq, out)
                        }
                    };
                    (out, "avgpool".to_string(), run, false)
                }
                LayerKind::Fc { k, n, name } => {
                    let out = sim.alloc((n.max(&64) * esz) as u64);
                    let run = match precision {
                        Precision::Fp32 => {
                            let w = sim.alloc((k * n * 4) as u64);
                            let b = sim.alloc((n * 4) as u64);
                            if write_data {
                                let wv: Vec<f32> = (0..k * n)
                                    .map(|_| (lcg(&mut seed) % 200) as f32 / 1000.0 - 0.1)
                                    .collect();
                                sim.write_f32s(w, &wv);
                                sim.write_f32s(b, &vec![0.01; *n]);
                            }
                            matmul_f32(sim, 1, *k, *n, input, w, b, out, false)
                        }
                        Precision::Int8 => {
                            let w = sim.alloc((k * n) as u64);
                            if write_data {
                                let wv: Vec<i8> =
                                    (0..k * n).map(|_| (lcg(&mut seed) % 256) as i8).collect();
                                sim.write_i8(w, &wv);
                            }
                            let rq = Self::rqbuf(sim, *n, *k, false);
                            matmul_int8(sim, 1, *k, *n, input, w, &rq, out)
                        }
                        Precision::Sub { abits, wbits, use_vbitpack } => {
                            let codes: Vec<u8> = if write_data {
                                (0..k * n).map(|_| (lcg(&mut seed) % (1 << wbits)) as u8).collect()
                            } else {
                                vec![0u8; k * n]
                            };
                            let block = bitserial_block(sim.cfg.vlen_bits, *n);
                            let wpk = pack_weight_planes(&codes, *k, *n, wbits, block);
                            let w = sim.alloc(wpk.byte_len() as u64);
                            if write_data {
                                for (i, &word) in wpk.words.iter().enumerate() {
                                    sim.machine.mem.write_u64_le(w + (i * 8) as u64, word, 8);
                                }
                            }
                            let rq = Self::rqbuf(sim, *n, *k, false);
                            matmul_bitserial(
                                sim, 1, *k, *n, abits, input, &wpk, w, &rq, out, use_vbitpack,
                                idx_vec,
                            )
                        }
                    };
                    (out, name.clone(), run, true)
                }
            };
            maps.push(out_addr);
            let stats = sim.stats().delta_since(&before);
            reports.push(LayerReport { name, quantized, run, stats });
        }
        let out_elems = net.last().map(|l| layer_out_elems(&l.kind)).unwrap_or(input_elems);
        ModelRun { reports, out_addr: *maps.last().unwrap(), out_elems }
    }

    /// Synthetic per-channel requant parameters that keep code values in a
    /// sane range: alpha ~ 1/K so accumulators map back onto the u8 grid.
    fn rqbuf(sim: &mut Sim, n: usize, k: usize, _relu: bool) -> RqBuf {
        let alpha = 1.0 / (k as f32).max(1.0);
        let alphas: Vec<f32> = (0..n).map(|j| alpha * (1.0 + (j % 7) as f32 * 0.01)).collect();
        let betas = vec![-alpha * 0.25; n];
        let biases = vec![0.5; n];
        RqBuf::create(sim, &alphas, &betas, &biases, 255.0, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::nn::resnet::resnet18_cifar;
    use crate::sim::SimMode;

    #[test]
    fn tiny_net_runs_all_precisions() {
        // A 2-layer slice of the graph exercises conv+pool+fc quickly.
        let net = vec![
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Conv(crate::nn::ConvLayer {
                    name: "c1".into(),
                    params: crate::kernels::Conv2dParams {
                        h: 8,
                        w: 8,
                        c_in: 64,
                        c_out: 64,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                    relu: true,
                    residual: false,
                    quantized: true,
                }),
                input: 0,
                residual_from: None,
            },
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::AvgPool { h: 8, w: 8, c: 64 },
                input: 1,
                residual_from: None,
            },
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Fc { k: 64, n: 10, name: "fc".into() },
                input: 2,
                residual_from: None,
            },
        ];
        // NOTE: map 0 in run() is always the 32×32×3 input buffer; this tiny
        // net reads garbage from it, which is fine for a smoke test.
        for (cfg, prec) in [
            (MachineConfig::ara(4), Precision::Fp32),
            (MachineConfig::ara(4), Precision::Int8),
            (MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true }),
        ] {
            let mut sim = Sim::new(cfg);
            sim.set_mode(SimMode::TimingOnly);
            let reports = ModelRunner::run(&mut sim, &net, prec, false);
            assert_eq!(reports.len(), 3);
            assert!(reports.iter().all(|r| r.run.cycles > 0), "{prec:?}");
        }
    }

    #[test]
    fn resnet18_graph_runs_timing_only_int1_faster_than_int8() {
        let net = resnet18_cifar(100);
        let cycles = |cfg: MachineConfig, prec: Precision| {
            let mut sim = Sim::new(cfg);
            sim.set_mode(SimMode::TimingOnly);
            let reports = ModelRunner::run(&mut sim, &net, prec, false);
            reports
                .iter()
                .filter(|r| r.quantized)
                .map(|r| r.run.cycles)
                .sum::<u64>()
        };
        let int8 = cycles(MachineConfig::ara(4), Precision::Int8);
        let int1 = cycles(
            MachineConfig::quark(4),
            Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true },
        );
        let speedup = int8 as f64 / int1 as f64;
        assert!(
            speedup > 3.0,
            "Int1 should be several times faster than Int8 (got {speedup:.2}x)"
        );
    }
}
