//! Model schedules + the live-execution entry points.
//!
//! The actual emission loop — materialize feature maps and weights in
//! simulated memory, emit every layer through the kernel matching its
//! resolved [`Precision`] — lives in [`crate::program::builder`] as the
//! single source of truth shared by this live path and the
//! compile-once/run-many path ([`crate::program::compile`] →
//! [`crate::sim::Sim::execute`]). [`ModelRunner::run_scheduled`] (and the
//! uniform wrappers [`ModelRunner::run`] / [`ModelRunner::run_with_input`])
//! are thin veneers over it: one fresh emission into the caller's
//! [`Sim`], reporting per-layer cycles. Serving-path callers that run the
//! same deployment repeatedly should compile once and replay instead (see
//! the coordinator's program cache).
//!
//! ## Per-layer precision
//!
//! A [`PrecisionMap`] assigns each Conv/FC layer its own `(weight_bits,
//! act_bits)` pair instead of one network-wide precision — the layer-wise
//! schedule space that SPEED (arXiv 2409.14017) and Ottavi et al.
//! (arXiv 2010.04073) show is where multi-precision hardware earns its area.
//! Two rules make mixed schedules compose:
//!
//! * **dispatch** — each layer is emitted through the kernel for *its*
//!   precision (bit-serial / int8 / fp32), with weights packed at that
//!   layer's `weight_bits` ([`crate::quant::pack_weight_planes`]);
//! * **re-pack at boundaries** — a layer's output is re-quantized onto the
//!   grid of its *narrowest consumer* ([`map_consumer_bits`]): when an 8-bit
//!   layer feeds a 2-bit one, the producer's requant clamps to `[0, 3]` so
//!   the stored codes are exact bit-plane inputs for the consumer's
//!   activation packing (`vbitpack` reads only `act_bits` planes).
//!
//! Mixed schedules are integer-only (fp32 changes the feature-map element
//! size); [`PrecisionMap::validate`] enforces this.

use crate::arch::MachineConfig;
use crate::kernels::KernelRun;
use crate::sim::{Sim, Stats};

use super::graph::NetGraph;
use super::resnet::{LayerKind, NetLayer};

/// Execution precision of one layer (or, via [`PrecisionMap::uniform`], of a
/// whole network).
///
/// `Eq + Hash` so precisions can key the coordinator's timing cache (the
/// enum carries only integers and booleans).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// FP32 baseline (requires the vector FPU — Ara).
    Fp32,
    /// Int8 baseline (integer-only; the paper runs it on Ara).
    Int8,
    /// Sub-byte bit-serial (requires the Quark ISA). `use_vbitpack = false`
    /// selects the pure-RVV packing fallback (Fig. 3 ablation).
    Sub { abits: u8, wbits: u8, use_vbitpack: bool },
}

impl Precision {
    pub fn label(&self) -> String {
        match self {
            Precision::Fp32 => "fp32".into(),
            Precision::Int8 => "int8".into(),
            Precision::Sub { abits, wbits, use_vbitpack } => {
                format!("w{wbits}a{abits}{}", if *use_vbitpack { "" } else { "-novbp" })
            }
        }
    }

    /// Parse a [`Precision::label`]-format string: `fp32`, `int8`, or
    /// `w<bits>a<bits>` with an optional `-novbp` suffix.
    ///
    /// ```
    /// use quark::nn::model::Precision;
    /// assert_eq!(Precision::parse("int8"), Ok(Precision::Int8));
    /// let p = Precision::parse("w2a1-novbp").unwrap();
    /// assert_eq!(p, Precision::Sub { abits: 1, wbits: 2, use_vbitpack: false });
    /// assert_eq!(Precision::parse(&p.label()), Ok(p));
    /// assert!(Precision::parse("w4a4").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s {
            "fp32" => Ok(Precision::Fp32),
            "int8" => Ok(Precision::Int8),
            _ => {
                let (core, use_vbitpack) = match s.strip_suffix("-novbp") {
                    Some(c) => (c, false),
                    None => (s, true),
                };
                let err = || format!("unknown precision {s:?} (want fp32, int8, or wNaM[-novbp])");
                let rest = core.strip_prefix('w').ok_or_else(err)?;
                let (w, a) = rest.split_once('a').ok_or_else(err)?;
                let wbits: u8 = w.parse().map_err(|_| err())?;
                let abits: u8 = a.parse().map_err(|_| err())?;
                if !(1..=2).contains(&wbits) || !(1..=2).contains(&abits) {
                    return Err(format!(
                        "sub-byte precision {s:?} out of range (1\u{2013}2 bits per operand)"
                    ));
                }
                Ok(Precision::Sub { abits, wbits, use_vbitpack })
            }
        }
    }

    /// Bits at which a kernel at this precision reads its input activation
    /// codes: a `Sub` kernel packs (and therefore sees) only `act_bits`
    /// planes; the integer and fp32 baselines read full 8-bit codes.
    pub fn act_bits(&self) -> u8 {
        match self {
            Precision::Fp32 | Precision::Int8 => 8,
            Precision::Sub { abits, .. } => *abits,
        }
    }
}

/// Per-layer precision assignment: a default plus named overrides.
///
/// Overrides are kept sorted by layer name, so two maps describing the same
/// schedule are `Eq`/`Hash`-identical — the coordinator keys its timing
/// cache with the map directly.
///
/// ```
/// use quark::nn::model::{Precision, PrecisionMap};
/// let map = PrecisionMap::parse("w2a2;fc=int8;stem=int8").unwrap();
/// assert_eq!(map.of("fc"), Precision::Int8);
/// assert_eq!(map.of("conv3"), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
/// assert_eq!(PrecisionMap::parse(&map.spec()), Ok(map));
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PrecisionMap {
    default: Precision,
    /// `(layer name, precision)`, sorted by name (canonical form).
    overrides: Vec<(String, Precision)>,
}

impl PrecisionMap {
    /// The classic single-precision run: every layer at `default`.
    pub fn uniform(default: Precision) -> Self {
        PrecisionMap { default, overrides: Vec::new() }
    }

    /// Builder-style [`PrecisionMap::set`].
    pub fn with(mut self, layer: &str, precision: Precision) -> Self {
        self.set(layer, precision);
        self
    }

    /// Override one layer's precision (replaces any earlier override).
    /// Setting a layer back to the default *removes* its override, keeping
    /// the map canonical: two maps describing the same schedule are always
    /// `Eq`/`Hash`-identical, so they share one timing-cache entry.
    pub fn set(&mut self, layer: &str, precision: Precision) {
        match self.overrides.binary_search_by(|(n, _)| n.as_str().cmp(layer)) {
            Ok(i) => {
                if precision == self.default {
                    self.overrides.remove(i);
                } else {
                    self.overrides[i].1 = precision;
                }
            }
            Err(i) => {
                if precision != self.default {
                    self.overrides.insert(i, (layer.to_string(), precision));
                }
            }
        }
    }

    /// The precision assigned to `layer`.
    pub fn of(&self, layer: &str) -> Precision {
        match self.overrides.binary_search_by(|(n, _)| n.as_str().cmp(layer)) {
            Ok(i) => self.overrides[i].1,
            Err(_) => self.default,
        }
    }

    pub fn default_precision(&self) -> Precision {
        self.default
    }

    pub fn overrides(&self) -> &[(String, Precision)] {
        &self.overrides
    }

    /// True when every layer resolves to the default. Because
    /// [`PrecisionMap::set`] drops redundant overrides, this is exactly
    /// "no overrides".
    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty()
    }

    /// Short display label: the precision label for uniform maps, a
    /// `mixed(default+N)` tag otherwise (no whitespace — used in wire
    /// replies).
    pub fn label(&self) -> String {
        if self.is_uniform() {
            self.default.label()
        } else {
            format!("mixed({}+{})", self.default.label(), self.overrides.len())
        }
    }

    /// Canonical spec string: `default[;layer=precision…]`. Inverse of
    /// [`PrecisionMap::parse`].
    pub fn spec(&self) -> String {
        let mut s = self.default.label();
        for (name, p) in &self.overrides {
            s.push(';');
            s.push_str(name);
            s.push('=');
            s.push_str(&p.label());
        }
        s
    }

    /// Parse a spec string (the `--precision` flag / `prec=` wire field):
    /// a default [`Precision`], then `;layer=precision` overrides.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut parts = spec.split(';');
        let default = Precision::parse(parts.next().unwrap_or("").trim())?;
        let mut map = PrecisionMap::uniform(default);
        for part in parts {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, prec) = part
                .split_once('=')
                .ok_or_else(|| format!("bad override {part:?} (want layer=precision)"))?;
            let name = name.trim();
            if name.is_empty() {
                return Err(format!("bad override {part:?} (empty layer name)"));
            }
            map.set(name, Precision::parse(prec.trim())?);
        }
        Ok(map)
    }

    /// Resolve the execution precision of every layer of `net`, in network
    /// order. The unquantized stem is pinned to int8 under every integer
    /// schedule (as the paper keeps input/output layers at "full precision");
    /// pooling has no precision of its own and follows the schedule family.
    pub fn resolve(&self, net: &[NetLayer]) -> Vec<Precision> {
        net.iter()
            .map(|l| match &l.kind {
                LayerKind::Conv(c) => {
                    let p = self.of(&c.name);
                    if !c.quantized && p != Precision::Fp32 {
                        Precision::Int8
                    } else {
                        p
                    }
                }
                LayerKind::AvgPool { .. } => {
                    if self.default == Precision::Fp32 {
                        Precision::Fp32
                    } else {
                        Precision::Int8
                    }
                }
                LayerKind::Fc { name, .. } => self.of(name),
            })
            .collect()
    }

    /// Check the map against a network: every override must name a real
    /// Conv/FC layer, sub-byte precisions must be within the paper's 1–2-bit
    /// range, and fp32 must not mix with integer layers (the feature-map
    /// element size differs, so a mixed graph could not share buffers).
    pub fn validate(&self, net: &[NetLayer]) -> Result<(), String> {
        for (name, _) in &self.overrides {
            let mut known = false;
            for l in net {
                match &l.kind {
                    LayerKind::Conv(c) if c.name == *name => {
                        // Overriding the unquantized stem would be a silent
                        // no-op (resolve() pins it to int8): reject instead,
                        // so syntactically different maps never describe the
                        // same resolved schedule.
                        if !c.quantized {
                            return Err(format!(
                                "layer {name:?} is unquantized (pinned to int8) and cannot be overridden"
                            ));
                        }
                        known = true;
                    }
                    LayerKind::Fc { name: n, .. } if n == name => known = true,
                    _ => {}
                }
            }
            if !known {
                return Err(format!("precision override names unknown layer {name:?}"));
            }
        }
        let resolved = self.resolve(net);
        let any_fp32 = resolved.iter().any(|p| *p == Precision::Fp32);
        let all_fp32 = resolved.iter().all(|p| *p == Precision::Fp32);
        // fp32 is only valid as the *default* of an all-fp32 schedule: the
        // runner derives the feature-map element size (and the serving layer
        // its logit encoding) from the default, so fp32 smuggled in through
        // overrides — or a fp32 default with integer layers — would mix
        // 1-byte and 4-byte maps in one graph.
        if any_fp32 && (self.default != Precision::Fp32 || !all_fp32) {
            return Err(
                "fp32 cannot mix with integer layers in one schedule (feature-map \
                 element size differs); use a uniform fp32 schedule"
                    .to_string(),
            );
        }
        for p in &resolved {
            if let Precision::Sub { abits, wbits, .. } = p {
                if !(1..=2).contains(abits) || !(1..=2).contains(wbits) {
                    return Err(format!(
                        "sub-byte precision w{wbits}a{abits} out of the supported 1\u{2013}2-bit range"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Check that `cfg` can execute this schedule on `net` (sub-byte layers
    /// need the Quark ISA, fp32 needs the vector FPU).
    pub fn validate_machine(&self, net: &[NetLayer], cfg: &MachineConfig) -> Result<(), String> {
        for p in self.resolve(net) {
            match p {
                Precision::Fp32 if !cfg.has_vfpu => {
                    return Err(format!(
                        "schedule needs the vector FPU (fp32) but machine {} has none",
                        cfg.name
                    ));
                }
                Precision::Sub { .. } if !cfg.has_quark_isa => {
                    return Err(format!(
                        "schedule needs the Quark ISA (sub-byte layers) but machine {} lacks it",
                        cfg.name
                    ));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Tag folded into the synthetic-parameter seed. Uniform maps keep the
    /// historical per-precision streams; mixed maps get their own family.
    pub(crate) fn seed_tag(&self) -> u64 {
        if self.is_uniform() {
            match self.default {
                Precision::Fp32 => 1,
                Precision::Int8 => 2,
                Precision::Sub { .. } => 3,
            }
        } else {
            5
        }
    }
}

/// Tensor-parallel shard plan: which layers' output channels are partitioned
/// across the cluster's shard cores, and how ([`crate::cluster`]).
///
/// The partition rule is the classic tensor-parallel split: every Conv/FC
/// layer's *output channels* are divided into `shards` contiguous ranges
/// (each shard reads the full input feature map and computes its range);
/// pooling has no channel-parallel work worth splitting at this scale and
/// runs replicated on every shard. At `shards == 1` no layer is partitioned
/// and a shard program is emission-identical to the single-core program.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    shards: usize,
    /// Per layer: `Some(full output channel count)` when the layer's output
    /// channels are partitioned; `None` when the layer runs replicated.
    channels: Vec<Option<usize>>,
}

impl ShardPlan {
    /// Derive the plan for `net` at `shards` cores, validating channel
    /// counts: every partitioned layer must have at least one output channel
    /// per shard (ranges are contiguous and may be uneven — e.g. a 10-class
    /// FC at 4 shards splits 2/3/2/3).
    pub fn derive(net: &NetGraph, shards: usize) -> Result<ShardPlan, String> {
        if shards == 0 {
            return Err("shard count must be ≥ 1".to_string());
        }
        let mut channels = Vec::with_capacity(net.len());
        for layer in net.layers() {
            let sharded = match &layer.kind {
                LayerKind::Conv(c) => Some((c.name.as_str(), c.params.c_out)),
                LayerKind::Fc { n, name, .. } => Some((name.as_str(), *n)),
                LayerKind::AvgPool { .. } => None,
            };
            match sharded {
                Some((name, c_out)) if shards > 1 => {
                    if c_out < shards {
                        return Err(format!(
                            "layer {name:?} has {c_out} output channels — fewer than {shards} shards"
                        ));
                    }
                    channels.push(Some(c_out));
                }
                _ => channels.push(None),
            }
        }
        Ok(ShardPlan { shards, channels })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn layers(&self) -> usize {
        self.channels.len()
    }

    /// Output-channel range `[c0, c1)` that `shard` computes for `layer`;
    /// `None` when the layer runs replicated (pooling, and every layer at
    /// `shards == 1`).
    pub fn range(&self, layer: usize, shard: usize) -> Option<(usize, usize)> {
        let n = self.channels[layer]?;
        Some((n * shard / self.shards, n * (shard + 1) / self.shards))
    }

    /// Check the schedule against the bit-plane re-pack rule: the inter-core
    /// all-gather moves raw u8 activation codes, and a gathered map stays on
    /// its narrowest-consumer grid ([`map_consumer_bits`]) only because
    /// channel slicing never re-quantizes — which holds for the integer
    /// schedules. fp32 feature maps (4-byte elements, no code grid) cannot
    /// shard.
    pub fn validate_schedule(&self, schedule: &PrecisionMap) -> Result<(), String> {
        if self.shards > 1 && schedule.default_precision() == Precision::Fp32 {
            return Err(
                "cluster sharding is integer-only: the activation all-gather exchanges \
                 u8 codes on the consumer bit-plane grid, which fp32 maps do not have"
                    .to_string(),
            );
        }
        Ok(())
    }
}

/// Pipeline-parallel stage plan: which contiguous layer range each stage
/// core of a pipelined cluster executes ([`crate::cluster::pipeline`]).
///
/// The partition rule is classic pipeline parallelism: the network's layers
/// are split into `stages` contiguous ranges, one per core, and activations
/// stream stage-to-stage. A cut before layer `l` is *valid* only when no
/// layer at or after `l` reads a feature map produced before map `l` —
/// map `l` is the single hand-off activation, so a residual (skip) edge
/// spanning the cut would force a second cross-stage fetch. Residual blocks
/// are therefore indivisible, mirroring how [`ShardPlan`] refuses plans its
/// runtime cannot execute. Ranges are chosen to minimize the maximum
/// per-stage cycle cost (the pipeline's steady-state period) over the valid
/// cuts, by dynamic programming on caller-supplied per-layer cycle
/// estimates from the timing model. At `stages == 1` the single range
/// covers the whole net and the stage program is emission-identical to the
/// single-core program.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StagePlan {
    stages: usize,
    /// Per stage: contiguous layer range `[lo, hi)`; ranges tile
    /// `0..layers` in order.
    ranges: Vec<(usize, usize)>,
    /// Total layer count of the net the plan was derived for.
    layers: usize,
}

impl StagePlan {
    /// Derive the cost-balanced plan for `net` at `stages` cores, given
    /// per-layer cycle estimates `costs` (network order). Errors mirror
    /// [`ShardPlan::derive`]: zero stages, more stages than layers, and
    /// nets whose residual topology does not admit enough valid cuts are
    /// all rejected with the human-readable reason.
    pub fn derive_balanced(
        net: &NetGraph,
        stages: usize,
        costs: &[u64],
    ) -> Result<StagePlan, String> {
        let n = net.len();
        if stages == 0 {
            return Err("stage count must be ≥ 1".to_string());
        }
        if stages > n {
            return Err(format!(
                "pipeline wants {stages} stages but the net has only {n} layers"
            ));
        }
        if costs.len() != n {
            return Err(format!(
                "cost vector covers {} layers but the net has {n}",
                costs.len()
            ));
        }
        // Valid cut points. earliest_ref[j] is the oldest feature map layer
        // `j` reads (its input, or its residual source when older); a cut
        // before layer `l` is usable iff min over j ≥ l of earliest_ref[j]
        // is ≥ l, answered for every l by one suffix-min pass.
        let layers = net.layers();
        let earliest_ref: Vec<usize> = layers
            .iter()
            .map(|l| l.residual_from.map_or(l.input, |r| r.min(l.input)))
            .collect();
        let mut cut_ok = vec![false; n + 1];
        cut_ok[0] = true;
        cut_ok[n] = true;
        let mut sufmin = usize::MAX;
        for l in (1..n).rev() {
            sufmin = sufmin.min(earliest_ref[l]);
            cut_ok[l] = sufmin >= l;
        }
        // Min-max partition over the valid cuts: dp[s][i] = the smallest
        // achievable max-stage cost splitting layers 0..i into s stages.
        let mut prefix = vec![0u64; n + 1];
        for (i, &c) in costs.iter().enumerate() {
            prefix[i + 1] = prefix[i] + c;
        }
        const INF: u64 = u64::MAX;
        let mut dp = vec![vec![INF; n + 1]; stages + 1];
        let mut cut = vec![vec![0usize; n + 1]; stages + 1];
        dp[0][0] = 0;
        for s in 1..=stages {
            for i in s..=n {
                if !cut_ok[i] {
                    continue;
                }
                for j in (s - 1)..i {
                    if !cut_ok[j] || dp[s - 1][j] == INF {
                        continue;
                    }
                    let v = dp[s - 1][j].max(prefix[i] - prefix[j]);
                    if v < dp[s][i] {
                        dp[s][i] = v;
                        cut[s][i] = j;
                    }
                }
            }
        }
        if dp[stages][n] == INF {
            let max_stages = (1..n).filter(|&l| cut_ok[l]).count() + 1;
            return Err(format!(
                "net supports at most {max_stages} pipeline stages (residual \
                 blocks are indivisible) — cannot form {stages}"
            ));
        }
        let mut ranges = vec![(0usize, 0usize); stages];
        let mut i = n;
        for s in (1..=stages).rev() {
            let j = cut[s][i];
            ranges[s - 1] = (j, i);
            i = j;
        }
        Ok(StagePlan { stages, ranges, layers: n })
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    /// Total layer count of the net the plan covers.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Layer range `[lo, hi)` that `stage` executes.
    pub fn range(&self, stage: usize) -> (usize, usize) {
        self.ranges[stage]
    }

    /// All stage ranges, in stage order (they tile `0..layers`).
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// The stage-boundary analogue of [`ShardPlan::validate_schedule`]: the
    /// inter-stage hand-off moves raw u8 activation codes, and a handed-off
    /// map stays on its narrowest-consumer grid ([`map_consumer_bits`],
    /// computed over the *full* net at compile time) only because the
    /// transfer never re-quantizes — which holds for the integer schedules.
    /// fp32 feature maps (4-byte elements, no code grid) cannot pipeline.
    pub fn validate_schedule(&self, schedule: &PrecisionMap) -> Result<(), String> {
        if self.stages > 1 && schedule.default_precision() == Precision::Fp32 {
            return Err(
                "pipeline parallelism is integer-only: stage hand-offs exchange \
                 u8 codes on the consumer bit-plane grid, which fp32 maps do not have"
                    .to_string(),
            );
        }
        Ok(())
    }
}

/// `2^bits − 1`: the top of a `bits`-bit unsigned code grid.
pub fn grid_qmax(bits: u8) -> u32 {
    (1u32 << bits) - 1
}

/// For every feature-map index (0 = network input; layer `i` writes map
/// `i + 1`), the narrowest activation precision at which any consumer layer
/// reads it — 8 when unconsumed (final logits are read as full u8 codes).
///
/// This is the re-pack rule of mixed-precision inference: layer `i`'s
/// requant clamps onto `[0, 2^bits − 1]` of `map_consumer_bits(..)[i + 1]`,
/// so stored codes are always exact under the consumer's `act_bits`-plane
/// packing. Residual (skip) inputs are read as full u8 codes by the requant
/// stage and impose no constraint.
pub fn map_consumer_bits(net: &[NetLayer], resolved: &[Precision]) -> Vec<u8> {
    let mut bits = vec![8u8; net.len() + 1];
    for (i, layer) in net.iter().enumerate() {
        let read = resolved[i].act_bits();
        if read < bits[layer.input] {
            bits[layer.input] = read;
        }
    }
    bits
}

/// Per-layer result of a model run.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub quantized: bool,
    /// Resolved execution precision of this layer.
    pub precision: Precision,
    /// Simulated address of this layer's output feature map.
    pub out_addr: u64,
    /// Logical element count of this layer's output.
    pub out_elems: usize,
    pub run: KernelRun,
    pub stats: Stats,
}

/// Deterministic pseudo-random generator for synthetic weights/inputs.
pub fn lcg(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *seed >> 33
}

/// Synthetic network input codes (u8), drawn from the deterministic stream.
pub(crate) fn synth_input(seed: &mut u64, n: usize) -> Vec<u8> {
    (0..n).map(|_| (lcg(seed) % 256) as u8).collect()
}

/// Synthetic fp32 weights in roughly `[-0.1, 0.1)`.
pub(crate) fn synth_f32(seed: &mut u64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (lcg(seed) % 200) as f32 / 1000.0 - 0.1).collect()
}

/// Synthetic signed int8 weights.
pub(crate) fn synth_i8(seed: &mut u64, n: usize) -> Vec<i8> {
    (0..n).map(|_| (lcg(seed) % 256) as i8).collect()
}

/// Synthetic unsigned sub-byte weight codes in `[0, 2^bits)`.
pub(crate) fn synth_codes(seed: &mut u64, n: usize, bits: u8) -> Vec<u8> {
    (0..n).map(|_| (lcg(seed) % (1u64 << bits)) as u8).collect()
}

/// Synthetic per-channel requant parameters that keep code values in a sane
/// range: alpha ~ 1/K so accumulators map back onto the output grid. Shared
/// by the runner and the host golden model ([`super::golden`]) so both see
/// identical scales.
pub(crate) fn synth_rq_params(n: usize, k: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let alpha = 1.0 / (k as f32).max(1.0);
    let alphas: Vec<f32> = (0..n).map(|j| alpha * (1.0 + (j % 7) as f32 * 0.01)).collect();
    let betas = vec![-alpha * 0.25; n];
    let biases = vec![0.5; n];
    (alphas, betas, biases)
}

/// Result of a whole-model run: the per-layer reports plus where the final
/// feature map (the logits, for classifier graphs) landed in simulated
/// memory — the serving layer reads real outputs from there.
#[derive(Clone, Debug)]
pub struct ModelRun {
    pub reports: Vec<LayerReport>,
    /// Simulated address of the last layer's output buffer.
    pub out_addr: u64,
    /// Logical element count of the last layer's output (e.g. class count).
    pub out_elems: usize,
}

pub struct ModelRunner;

impl ModelRunner {
    /// Run a model graph (see [`crate::nn::zoo`]) at one uniform precision;
    /// batch 1, synthetic weights + synthetic input. Use `TimingOnly` mode
    /// for cycle-only sweeps — cycle counts are identical to `Full` (the
    /// kernels are data-independent).
    pub fn run(sim: &mut Sim, net: &NetGraph, precision: Precision) -> Vec<LayerReport> {
        Self::run_scheduled(sim, net, &PrecisionMap::uniform(precision), None).reports
    }

    /// Like [`Self::run`], but with an optional explicit network input
    /// (CIFAR-sized u8 codes; shorter inputs are zero-padded, longer ones
    /// truncated). Returns the output buffer location so callers can read
    /// real logits after a `Full`-mode run.
    pub fn run_with_input(
        sim: &mut Sim,
        net: &NetGraph,
        precision: Precision,
        input: Option<&[u8]>,
    ) -> ModelRun {
        Self::run_scheduled(sim, net, &PrecisionMap::uniform(precision), input)
    }

    /// Run `net` under a per-layer [`PrecisionMap`]: one fresh emission
    /// through the shared model-emission routine
    /// ([`crate::program::builder`]). Synthetic weights are drawn from one
    /// deterministic stream (a function of the schedule family only), so
    /// two runs under the same schedule differ only in the input feature
    /// map. Panics on schedules that fail [`PrecisionMap::validate`] /
    /// [`PrecisionMap::validate_machine`] — the serving layer pre-validates
    /// at submission.
    pub fn run_scheduled(
        sim: &mut Sim,
        net: &NetGraph,
        schedule: &PrecisionMap,
        input: Option<&[u8]>,
    ) -> ModelRun {
        let emitted = crate::program::builder::emit_model(sim, net, schedule, input, None, None);
        ModelRun {
            reports: emitted.reports,
            out_addr: emitted.out_addr,
            out_elems: emitted.out_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::MachineConfig;
    use crate::nn::resnet::resnet18_cifar;
    use crate::sim::SimMode;

    /// stem + conv + pool + fc: every layer kind, valid shapes end to end.
    fn tiny_layers() -> Vec<crate::nn::NetLayer> {
        let conv = |name: &str, c_in: usize, quantized: bool| crate::nn::ConvLayer {
            name: name.into(),
            params: crate::kernels::Conv2dParams {
                h: 8,
                w: 8,
                c_in,
                c_out: 64,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            relu: true,
            residual: false,
            quantized,
        };
        vec![
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Conv(conv("stem", 3, false)),
                input: 0,
                residual_from: None,
            },
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Conv(conv("c1", 64, true)),
                input: 1,
                residual_from: None,
            },
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::AvgPool { h: 8, w: 8, c: 64 },
                input: 2,
                residual_from: None,
            },
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Fc { k: 64, n: 10, name: "fc".into() },
                input: 3,
                residual_from: None,
            },
        ]
    }

    fn tiny_graph() -> NetGraph {
        NetGraph::new("tiny-test@10", 10, tiny_layers()).unwrap()
    }

    #[test]
    fn tiny_net_runs_all_precisions() {
        let net = tiny_graph();
        for (cfg, prec) in [
            (MachineConfig::ara(4), Precision::Fp32),
            (MachineConfig::ara(4), Precision::Int8),
            (MachineConfig::quark(4), Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true }),
        ] {
            let mut sim = Sim::new(cfg);
            sim.set_mode(SimMode::TimingOnly);
            let reports = ModelRunner::run(&mut sim, &net, prec);
            assert_eq!(reports.len(), 4);
            assert!(reports.iter().all(|r| r.run.cycles > 0), "{prec:?}");
        }
    }

    #[test]
    fn mixed_schedule_dispatches_per_layer() {
        let net = tiny_graph();
        let map = PrecisionMap::uniform(Precision::Sub {
            abits: 2,
            wbits: 2,
            use_vbitpack: true,
        })
        .with("fc", Precision::Int8);
        let mut sim = Sim::new(MachineConfig::quark(4));
        sim.set_mode(SimMode::TimingOnly);
        let run = ModelRunner::run_scheduled(&mut sim, &net, &map, None);
        assert_eq!(run.reports[1].precision.label(), "w2a2");
        assert_eq!(run.reports[3].precision.label(), "int8");
        assert!(run.reports.iter().all(|r| r.run.cycles > 0));
    }

    #[test]
    fn resnet18_graph_runs_timing_only_int1_faster_than_int8() {
        let net = crate::nn::zoo::model("resnet18-cifar@100").unwrap();
        let cycles = |cfg: MachineConfig, prec: Precision| {
            let mut sim = Sim::new(cfg);
            sim.set_mode(SimMode::TimingOnly);
            let reports = ModelRunner::run(&mut sim, &net, prec);
            reports
                .iter()
                .filter(|r| r.quantized)
                .map(|r| r.run.cycles)
                .sum::<u64>()
        };
        let int8 = cycles(MachineConfig::ara(4), Precision::Int8);
        let int1 = cycles(
            MachineConfig::quark(4),
            Precision::Sub { abits: 1, wbits: 1, use_vbitpack: true },
        );
        let speedup = int8 as f64 / int1 as f64;
        assert!(
            speedup > 3.0,
            "Int1 should be several times faster than Int8 (got {speedup:.2}x)"
        );
    }

    #[test]
    fn precision_map_parse_validate_and_consumer_bits() {
        let net = tiny_layers();
        let map = PrecisionMap::parse("int8;c1=w2a2").unwrap();
        assert!(!map.is_uniform());
        assert_eq!(map.spec(), "int8;c1=w2a2");
        assert!(map.validate(&net).is_ok());
        assert!(PrecisionMap::parse("int8;ghost=w2a2").unwrap().validate(&net).is_err());
        assert!(PrecisionMap::parse("fp32;c1=int8").unwrap().validate(&net).is_err());
        // fp32 smuggled in through overrides must be rejected even when every
        // quantized layer resolves to fp32 — the element size follows the
        // default.
        assert!(PrecisionMap::parse("int8;c1=fp32;fc=fp32").unwrap().validate(&net).is_err());
        let fc_net = vec![crate::nn::NetLayer {
            kind: crate::nn::LayerKind::Fc { k: 64, n: 10, name: "fc".into() },
            input: 0,
            residual_from: None,
        }];
        assert!(PrecisionMap::parse("int8;fc=fp32").unwrap().validate(&fc_net).is_err());
        assert!(PrecisionMap::parse("w9a9").is_err());
        // Overrides may only name quantized layers: the stem is pinned, so a
        // stem override would be a silent no-op with a misleading label.
        let rnet = resnet18_cifar(10);
        assert!(PrecisionMap::parse("int8;stem=w2a2").unwrap().validate(&rnet).is_err());

        // Redundant overrides collapse to canonical form: the same schedule
        // is always the same map (and the same timing-cache key).
        let redundant = PrecisionMap::parse("int8;c1=w2a2;fc=int8").unwrap();
        assert_eq!(redundant, map);
        let mut back = map.clone();
        back.set("c1", Precision::Int8);
        assert_eq!(back, PrecisionMap::uniform(Precision::Int8));
        assert!(back.is_uniform());
        assert!(map.validate_machine(&net, &MachineConfig::quark(4)).is_ok());
        assert!(map.validate_machine(&net, &MachineConfig::ara(4)).is_err());

        // stem reads map 0 at 8 bits; c1 reads map 1 at 2; pool and fc read
        // their inputs at 8; the logits map is unconsumed (8).
        let resolved = map.resolve(&net);
        let bits = map_consumer_bits(&net, &resolved);
        assert_eq!(bits, vec![8, 2, 8, 8, 8]);
        assert_eq!(grid_qmax(2), 3);
        assert_eq!(grid_qmax(8), 255);
    }

    #[test]
    fn shard_plan_partitions_conv_and_fc_only() {
        let net = tiny_graph(); // stem + conv(64 ch) + pool + fc(10 classes)
        let plan = ShardPlan::derive(&net, 4).unwrap();
        assert_eq!(plan.shards(), 4);
        assert_eq!(plan.layers(), 4);
        // Convs: 64 channels split 16/16/16/16.
        assert_eq!(plan.range(1, 0), Some((0, 16)));
        assert_eq!(plan.range(1, 3), Some((48, 64)));
        // Pool is replicated.
        assert_eq!(plan.range(2, 2), None);
        // FC: 10 classes split unevenly but contiguously, covering all.
        let ranges: Vec<_> = (0..4).map(|s| plan.range(3, s).unwrap()).collect();
        assert_eq!(ranges, vec![(0, 2), (2, 5), (5, 7), (7, 10)]);
        assert_eq!(ranges.iter().map(|(a, b)| b - a).sum::<usize>(), 10);

        // shards == 1: nothing is partitioned (the single-core identity).
        let one = ShardPlan::derive(&net, 1).unwrap();
        assert!((0..4).all(|l| one.range(l, 0).is_none()));
    }

    #[test]
    fn shard_plan_validates_channel_counts_and_schedules() {
        let net = tiny_graph();
        assert!(ShardPlan::derive(&net, 0).is_err(), "0 shards is meaningless");
        // FC has 10 classes: 16 shards cannot each own a channel.
        let err = ShardPlan::derive(&net, 16).unwrap_err();
        assert!(err.contains("fewer than 16 shards"), "{err}");
        // fp32 cannot shard (no u8 code grid to all-gather on).
        let plan = ShardPlan::derive(&net, 2).unwrap();
        assert!(plan.validate_schedule(&PrecisionMap::uniform(Precision::Fp32)).is_err());
        assert!(plan.validate_schedule(&PrecisionMap::uniform(Precision::Int8)).is_ok());
        // At 1 shard even fp32 is fine (the plan is the identity).
        let one = ShardPlan::derive(&net, 1).unwrap();
        assert!(one.validate_schedule(&PrecisionMap::uniform(Precision::Fp32)).is_ok());
    }

    #[test]
    fn stage_plan_balances_costs_over_valid_cuts() {
        let net = tiny_graph(); // 4 sequential layers, every cut valid
        let plan = StagePlan::derive_balanced(&net, 2, &[10, 10, 10, 10]).unwrap();
        assert_eq!(plan.stages(), 2);
        assert_eq!(plan.layers(), 4);
        assert_eq!(plan.ranges(), &[(0, 2), (2, 4)]);
        // A heavy first layer pulls the first cut forward: min-max picks
        // {30} | {10, 10, 10} over {30, 10} | {10, 10}.
        let skewed = StagePlan::derive_balanced(&net, 2, &[30, 10, 10, 10]).unwrap();
        assert_eq!(skewed.ranges(), &[(0, 1), (1, 4)]);
        // stages == 1: one range covering the whole net.
        let one = StagePlan::derive_balanced(&net, 1, &[1, 1, 1, 1]).unwrap();
        assert_eq!(one.ranges(), &[(0, 4)]);
        // Degenerate requests are rejected with readable reasons.
        assert!(StagePlan::derive_balanced(&net, 0, &[1, 1, 1, 1]).is_err());
        let err = StagePlan::derive_balanced(&net, 5, &[1, 1, 1, 1]).unwrap_err();
        assert!(err.contains("only 4 layers"), "{err}");
        assert!(StagePlan::derive_balanced(&net, 2, &[1, 1]).is_err(), "cost len");
    }

    #[test]
    fn stage_plan_never_cuts_through_a_residual_block() {
        // stem → c1 → c2(+skip from map 1) → pool → fc: the skip edge spans
        // map 2, so the cut before layer 2 is invalid; all others are fine.
        let mut layers = tiny_layers();
        let c2 = crate::nn::ConvLayer {
            name: "c2".into(),
            residual: true,
            ..match &layers[1].kind {
                crate::nn::LayerKind::Conv(c) => c.clone(),
                _ => unreachable!(),
            }
        };
        layers.insert(
            2,
            crate::nn::NetLayer {
                kind: crate::nn::LayerKind::Conv(c2),
                input: 2,
                residual_from: Some(1),
            },
        );
        layers[3].input = 3;
        layers[4].input = 4;
        let net = NetGraph::new("res-test@10", 10, layers).unwrap();
        // Uniform costs would prefer the (invalid) cut before layer 2 at 2
        // stages ({2}|{3} split is impossible): the plan must route around
        // it.
        let plan = StagePlan::derive_balanced(&net, 2, &[1; 5]).unwrap();
        for s in 0..plan.stages() {
            let (lo, _) = plan.range(s);
            assert_ne!(lo, 2, "cut through the residual block");
        }
        // 4 stages exist (cuts at 1, 3, 4); 5 would need the forbidden cut.
        assert!(StagePlan::derive_balanced(&net, 4, &[1; 5]).is_ok());
        let err = StagePlan::derive_balanced(&net, 5, &[1; 5]).unwrap_err();
        assert!(err.contains("at most 4 pipeline stages"), "{err}");
    }

    #[test]
    fn stage_plan_rejects_fp32_at_multiple_stages() {
        let net = tiny_graph();
        let two = StagePlan::derive_balanced(&net, 2, &[1; 4]).unwrap();
        assert!(two.validate_schedule(&PrecisionMap::uniform(Precision::Fp32)).is_err());
        assert!(two.validate_schedule(&PrecisionMap::uniform(Precision::Int8)).is_ok());
        let one = StagePlan::derive_balanced(&net, 1, &[1; 4]).unwrap();
        assert!(one.validate_schedule(&PrecisionMap::uniform(Precision::Fp32)).is_ok());
    }

    #[test]
    fn netgraph_runner_emits_identically_to_the_raw_layer_list() {
        // Default-path regression guard: driving the shared emission routine
        // through the `NetGraph` wrapper must report exactly the cycle
        // counts of driving it with the bare layer list (the pre-redesign
        // workload representation) — the identity wrapper adds nothing.
        let graph = crate::nn::zoo::model("resnet18-cifar@100").unwrap();
        let raw = resnet18_cifar(100);
        assert_eq!(
            crate::nn::structural_fingerprint(&graph),
            crate::nn::structural_fingerprint(&raw),
            "the zoo graph must be the exact paper topology"
        );
        let sched = PrecisionMap::uniform(Precision::Sub {
            abits: 2,
            wbits: 2,
            use_vbitpack: true,
        });
        let mut sim_g = Sim::new(MachineConfig::quark(4));
        sim_g.set_mode(SimMode::TimingOnly);
        let via_graph = ModelRunner::run_scheduled(&mut sim_g, &graph, &sched, None);
        let mut sim_r = Sim::new(MachineConfig::quark(4));
        sim_r.set_mode(SimMode::TimingOnly);
        let via_raw =
            crate::program::builder::emit_model(&mut sim_r, &raw, &sched, None, None, None);
        assert_eq!(via_graph.reports.len(), via_raw.reports.len());
        for (g, r) in via_graph.reports.iter().zip(via_raw.reports.iter()) {
            assert_eq!(g.name, r.name);
            assert_eq!(g.run.cycles, r.run.cycles, "cycle drift at layer {}", g.name);
            assert_eq!(g.stats, r.stats, "stat drift at layer {}", g.name);
        }
    }
}
