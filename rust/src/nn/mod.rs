//! Model graphs executed on the vector DNN runtime.
//!
//! [`graph`] defines [`NetGraph`] — the validated, named, fingerprinted
//! model identity every consumer (runner, compiler, golden model, serving
//! layer, reports) takes instead of a bare layer list; [`zoo`] is the
//! registry of named, spec-parseable models (`resnet18-cifar@100`,
//! `quarknet`, `mlp`, `tiny`, …) with the `--fast` truncation profile.
//! [`resnet`] defines the ResNet CIFAR topologies the paper benchmarks
//! (Fig. 3: per-layer speedups on ResNet-18 / CIFAR-100, batch 1) plus the
//! mixed per-layer schedule ([`resnet::resnet18_mixed_schedule`]);
//! [`model`] materializes weights/scales and runs a graph on a simulated
//! machine under a uniform precision or a per-layer [`PrecisionMap`];
//! [`golden`] is the naive-i128 host reference the differential tests
//! compare against.

pub mod golden;
pub mod graph;
pub mod model;
pub mod resnet;
pub mod zoo;

pub use graph::{structural_fingerprint, NetGraph, INPUT_ELEMS};
pub use model::{LayerReport, ModelRun, ModelRunner, Precision, PrecisionMap, ShardPlan};
pub use resnet::{
    resnet18_cifar, resnet18_mixed_schedule, resnet34_cifar, ConvLayer, LayerKind, NetLayer,
};
