//! Model graphs executed on the vector DNN runtime.
//!
//! [`resnet`] defines the ResNet-18 CIFAR topology the paper benchmarks
//! (Fig. 3: per-layer speedups on ResNet-18 / CIFAR-100, batch 1) plus the
//! mixed per-layer schedule ([`resnet::resnet18_mixed_schedule`]);
//! [`model`] materializes weights/scales and runs the graph on a simulated
//! machine under a uniform precision or a per-layer [`PrecisionMap`];
//! [`golden`] is the naive-i128 host reference the mixed-precision
//! differential tests compare against.

pub mod golden;
pub mod model;
pub mod resnet;

pub use model::{LayerReport, ModelRun, ModelRunner, Precision, PrecisionMap, ShardPlan};
pub use resnet::{resnet18_cifar, resnet18_mixed_schedule, ConvLayer, LayerKind, NetLayer};
