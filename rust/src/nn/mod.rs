//! Model graphs executed on the vector DNN runtime.
//!
//! [`resnet`] defines the ResNet-18 CIFAR topology the paper benchmarks
//! (Fig. 3: per-layer speedups on ResNet-18 / CIFAR-100, batch 1);
//! [`model`] materializes weights/scales and runs the graph on a simulated
//! machine at a chosen precision.

pub mod model;
pub mod resnet;

pub use model::{LayerReport, ModelRun, ModelRunner, Precision};
pub use resnet::{resnet18_cifar, ConvLayer, LayerKind, NetLayer};
