//! The model zoo — named, spec-parseable [`NetGraph`] builders.
//!
//! Sparq and SPEED (arXiv 2409.14017) evaluate their vector processors as
//! *general* multi-precision DNN engines across several topologies; this
//! registry gives the reproduction the same surface. Every consumer that
//! used to hardcode `resnet18_cifar(100)` — the coordinator, the reports,
//! the benches, the cluster sweep — now resolves a model by **spec**:
//!
//! ```text
//! <name>[@<classes>]        e.g. resnet18-cifar@100, quarknet, mlp@10
//! ```
//!
//! and a new model is one [`ZooEntry`] line. The registry also owns the
//! `--fast` truncation profile (a per-model prefix length for quick smoke
//! runs), which replaces the `.take(8)` fast paths that used to be
//! copy-pasted across `cli.rs`.
//!
//! | name | topology | default classes |
//! |---|---|---|
//! | `resnet18-cifar` | the paper's workload ([`resnet18_cifar`]) | 100 |
//! | `resnet34-cifar` | deeper `[3,4,6,3]` variant ([`resnet34_cifar`]) | 100 |
//! | `quarknet` | VGG-style plain feedforward (6 convs, stride-2 downsampling) | 100 |
//! | `mlp` | 3-layer fully-connected stack over the raw input plane | 10 |
//! | `tiny` | the serving demo net (4 convs + pool + FC) | 100 |
//! | `attn-tiny` | integer attention-block surrogate (deep uniform FC stack) | 100 |
//!
//! All integer-quantized layers keep `K % 64 == 0` (word-aligned bit
//! planes) and every graph reads the shared [`INPUT_ELEMS`]-byte input
//! plane, so any zoo model runs under any integer [`PrecisionMap`] and any
//! shard count the channel widths allow.

use crate::kernels::Conv2dParams;
use crate::nn::model::PrecisionMap;
use crate::nn::resnet::{resnet18_cifar, resnet34_cifar, ConvLayer, LayerKind, NetLayer};

use super::graph::{NetGraph, INPUT_ELEMS};

/// One registered model: a named layer-list builder plus its registry
/// metadata.
pub struct ZooEntry {
    /// Registry name (the part of the spec before `@`).
    pub name: &'static str,
    /// Classes used when the spec does not carry `@<classes>`.
    pub default_classes: usize,
    /// One-line description (the `MODELS`/README listing).
    pub about: &'static str,
    build: fn(usize) -> Vec<NetLayer>,
    /// Leading layers kept under the `--fast` truncation profile.
    pub fast_layers: usize,
}

const ENTRIES: &[ZooEntry] = &[
    ZooEntry {
        name: "resnet18-cifar",
        default_classes: 100,
        about: "ResNet-18 CIFAR variant — the paper's Fig. 3 workload",
        build: resnet18_cifar,
        fast_layers: 8,
    },
    ZooEntry {
        name: "resnet34-cifar",
        default_classes: 100,
        about: "ResNet-34 CIFAR variant ([3,4,6,3] basic blocks)",
        build: resnet34_cifar,
        fast_layers: 8,
    },
    ZooEntry {
        name: "quarknet",
        default_classes: 100,
        about: "VGG-style plain feedforward: 6 convs, stride-2 downsampling",
        build: quarknet,
        fast_layers: 4,
    },
    ZooEntry {
        name: "mlp",
        default_classes: 10,
        about: "3-layer FC stack over the raw input plane",
        build: mlp,
        fast_layers: 3,
    },
    ZooEntry {
        name: "tiny",
        default_classes: 100,
        about: "serving demo net: 4 convs + pool + FC",
        build: tiny,
        fast_layers: 6,
    },
    ZooEntry {
        name: "attn-tiny",
        default_classes: 100,
        about: "integer attention-block surrogate: 3 blocks of QKV/score/FFN GEMMs, \
                softmax-free requant normalization",
        build: attn_tiny,
        fast_layers: 8,
    },
];

/// Every registered entry, in listing order.
pub fn entries() -> &'static [ZooEntry] {
    ENTRIES
}

/// Registered model names, in listing order.
pub fn names() -> Vec<&'static str> {
    ENTRIES.iter().map(|e| e.name).collect()
}

/// Look up a registry entry by bare name (no `@classes` suffix).
pub fn entry(name: &str) -> Option<&'static ZooEntry> {
    ENTRIES.iter().find(|e| e.name == name)
}

/// Resolve a model spec (`name[@classes]`) to its full graph.
pub fn model(spec: &str) -> Result<NetGraph, String> {
    model_profile(spec, false)
}

/// Resolve a model spec under a profile: `fast = true` keeps only the
/// entry's `fast_layers`-layer prefix — the registry-level smoke profile
/// every `--fast` flag maps to. The graph keeps its canonical name (the
/// truncation is visible in the fingerprint, not the identity).
pub fn model_profile(spec: &str, fast: bool) -> Result<NetGraph, String> {
    let (e, classes) = resolve(spec)?;
    build_graph(e, classes, if fast { e.fast_layers } else { usize::MAX })
}

/// Resolve a model spec truncated to its first `keep` layers (≥ 1) — the
/// generalized form of the `--fast` profile, for tests that need a
/// `Full`-mode-affordable head of a deep graph.
pub fn model_head(spec: &str, keep: usize) -> Result<NetGraph, String> {
    let (e, classes) = resolve(spec)?;
    build_graph(e, classes, keep)
}

/// Shared spec resolution: parse `name[@classes]`, look the name up, apply
/// the entry's default class count.
fn resolve(spec: &str) -> Result<(&'static ZooEntry, usize), String> {
    let (name, classes) = parse_spec(spec)?;
    let e = entry(name).ok_or_else(|| {
        format!("unknown model {name:?} (registered: {})", names().join(", "))
    })?;
    Ok((e, classes.unwrap_or(e.default_classes)))
}

fn build_graph(e: &ZooEntry, classes: usize, keep: usize) -> Result<NetGraph, String> {
    if !(2..=1024).contains(&classes) {
        return Err(format!("class count {classes} out of range (2\u{2013}1024)"));
    }
    if keep == 0 {
        return Err("cannot truncate a model to 0 layers".to_string());
    }
    let mut layers = (e.build)(classes);
    if keep < layers.len() {
        layers.truncate(keep);
    }
    NetGraph::new(&format!("{}@{classes}", e.name), classes, layers)
        .map_err(|err| format!("zoo model {:?} failed validation: {err}", e.name))
}

/// Parse `name[@classes]`. Every malformed shape is rejected with its own
/// reason instead of falling through to a misleading "unknown model" (empty
/// name) or a late range check (zero classes): empty name, empty class
/// count, non-numeric class count (which also catches trailing garbage like
/// `tiny@100x` or `tiny@100 extra`), and an explicit zero.
fn parse_spec(spec: &str) -> Result<(&str, Option<usize>), String> {
    let spec = spec.trim();
    let (name, classes) = match spec.split_once('@') {
        None => (spec, None),
        Some((name, c)) => {
            if c.is_empty() {
                return Err(format!(
                    "bad model spec {spec:?}: empty class count (want name[@classes])"
                ));
            }
            if !c.bytes().all(|b| b.is_ascii_digit()) {
                return Err(format!(
                    "bad model spec {spec:?}: class count {c:?} is not a number \
                     (want name[@classes])"
                ));
            }
            let classes: usize = c
                .parse()
                .map_err(|_| format!("bad model spec {spec:?}: class count {c:?} out of range"))?;
            if classes == 0 {
                return Err(format!("bad model spec {spec:?}: class count must be ≥ 1"));
            }
            (name, Some(classes))
        }
    };
    if name.is_empty() {
        return Err(format!("bad model spec {spec:?}: empty model name (want name[@classes])"));
    }
    Ok((name, classes))
}

fn conv(name: &str, h: usize, c_in: usize, c_out: usize, stride: usize, quantized: bool) -> ConvLayer {
    ConvLayer {
        name: name.into(),
        params: Conv2dParams { h, w: h, c_in, c_out, kh: 3, kw: 3, stride, pad: 1 },
        relu: true,
        residual: false,
        quantized,
    }
}

/// VGG-style plain feedforward net: no residuals, stride-2 convs do the
/// downsampling (there is no spatial-pool layer kind), global average pool
/// + classifier at the end. Every quantized K axis is a multiple of 64.
fn quarknet(num_classes: usize) -> Vec<NetLayer> {
    vec![
        NetLayer { kind: LayerKind::Conv(conv("stem", 32, 3, 64, 1, false)), input: 0, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c1", 32, 64, 64, 2, true)), input: 1, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c2", 16, 64, 128, 1, true)), input: 2, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c3", 16, 128, 128, 2, true)), input: 3, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c4", 8, 128, 256, 1, true)), input: 4, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c5", 8, 256, 256, 2, true)), input: 5, residual_from: None },
        NetLayer { kind: LayerKind::AvgPool { h: 4, w: 4, c: 256 }, input: 6, residual_from: None },
        NetLayer { kind: LayerKind::Fc { k: 256, n: num_classes, name: "fc".into() }, input: 7, residual_from: None },
    ]
}

/// 3-layer fully-connected stack reading the whole input plane: the
/// smallest non-conv topology (every layer a GEMM; K axes 3072/512/256,
/// all 64-aligned).
fn mlp(num_classes: usize) -> Vec<NetLayer> {
    vec![
        NetLayer {
            kind: LayerKind::Fc { k: INPUT_ELEMS, n: 512, name: "fc1".into() },
            input: 0,
            residual_from: None,
        },
        NetLayer { kind: LayerKind::Fc { k: 512, n: 256, name: "fc2".into() }, input: 1, residual_from: None },
        NetLayer {
            kind: LayerKind::Fc { k: 256, n: num_classes, name: "fc".into() },
            input: 2,
            residual_from: None,
        },
    ]
}

/// The serving demo net, promoted from the coordinator's private builder:
/// 4 convs (stride-2 downsampling) + pool + FC — full ResNet-18 per request
/// is a multi-second simulation; this keeps the serving path interactive
/// while exercising every kernel.
fn tiny(num_classes: usize) -> Vec<NetLayer> {
    vec![
        NetLayer { kind: LayerKind::Conv(conv("stem", 32, 3, 64, 1, false)), input: 0, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c1", 32, 64, 64, 2, true)), input: 1, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c2", 16, 64, 128, 2, true)), input: 2, residual_from: None },
        NetLayer { kind: LayerKind::Conv(conv("c3", 8, 128, 128, 2, true)), input: 3, residual_from: None },
        NetLayer { kind: LayerKind::AvgPool { h: 4, w: 4, c: 128 }, input: 4, residual_from: None },
        NetLayer { kind: LayerKind::Fc { k: 128, n: num_classes, name: "fc".into() }, input: 5, residual_from: None },
    ]
}

/// Integer-only attention-block surrogate — the deep *uniform* FC stack the
/// CNN zoo cannot provide, built for pipeline-parallel scaling
/// ([`crate::cluster::pipeline`]). One embedding GEMM folds the input plane
/// to `d_model = 512`, then 3 attention-shaped blocks, then the classifier:
///
/// * `q`/`k`/`v` — the projection GEMMs;
/// * `score` — the QK^T-shaped contraction, run as an int8 GEMM through the
///   existing matmul kernel (batch-1 serving collapses the sequence axis,
///   so its `[512 × 512]` shape stands in for the attention map);
/// * `attn_out` — the output projection;
/// * `ffn_up`/`ffn_down` — the `512 → 768 → 512` feed-forward pair.
///
/// There is no exp/softmax anywhere: normalization is *softmax-free*,
/// folded into the `score` layer's per-channel requant scale (a
/// shift-style rescale on the output code grid — the integer-only
/// normalization trick sub-byte accelerators use in place of a float
/// softmax). Weights are synthetic everywhere in this codebase, so the
/// stack is shape- and schedule-true rather than semantics-true: what it
/// exercises is 23 uniform GEMMs whose K axes (3072/512/768) are all
/// 64-bit-plane aligned and whose near-equal per-layer cost is exactly the
/// profile that pipeline stages balance well and tensor sharding cannot
/// accelerate past one request in flight.
fn attn_tiny(num_classes: usize) -> Vec<NetLayer> {
    const D: usize = 512;
    const FFN: usize = 768;
    fn push_fc(layers: &mut Vec<NetLayer>, k: usize, n: usize, name: String) {
        let input = layers.len();
        layers.push(NetLayer { kind: LayerKind::Fc { k, n, name }, input, residual_from: None });
    }
    let mut layers = Vec::with_capacity(23);
    push_fc(&mut layers, INPUT_ELEMS, D, "embed".into());
    for b in 0..3 {
        for (k, n, suffix) in [
            (D, D, "q"),
            (D, D, "k"),
            (D, D, "v"),
            (D, D, "score"),
            (D, D, "attn_out"),
            (D, FFN, "ffn_up"),
            (FFN, D, "ffn_down"),
        ] {
            push_fc(&mut layers, k, n, format!("b{b}_{suffix}"));
        }
    }
    push_fc(&mut layers, D, num_classes, "fc".into());
    layers
}

/// The generic mixed schedule for any zoo model: stage-1 convolutions
/// (`_s1` names) and every FC layer at int8, everything else 2-bit — for
/// ResNet graphs this is exactly
/// [`crate::nn::resnet::resnet18_mixed_schedule`], whose name-pattern
/// rules it reuses. Note the FC rule means an all-FC graph (`mlp`)
/// *resolves* to uniform int8 — still a distinct schedule key, but tests
/// wanting a genuine sub-byte/int8 boundary on such graphs should build
/// their own map.
pub fn mixed_schedule(net: &NetGraph) -> PrecisionMap {
    crate::nn::resnet::resnet18_mixed_schedule(net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet::quantized_layers;

    #[test]
    fn every_entry_resolves_under_both_profiles() {
        for e in entries() {
            let full = model(e.name).unwrap_or_else(|err| panic!("{}: {err}", e.name));
            assert_eq!(full.name(), format!("{}@{}", e.name, e.default_classes));
            assert_eq!(full.num_classes(), e.default_classes);
            let fast = model_profile(e.name, true).unwrap();
            assert!(fast.len() <= full.len());
            assert_eq!(fast.len(), e.fast_layers.min(full.len()));
            assert_eq!(fast.name(), full.name(), "profiles share the wire identity");
            if fast.len() != full.len() {
                assert_ne!(fast.fingerprint(), full.fingerprint());
            }
            // Every quantized K axis is 64-aligned in every registered model.
            for (name, p) in quantized_layers(&full) {
                assert_eq!(p.k() % 64, 0, "{}: {name} K={}", e.name, p.k());
            }
            // The generic mixed schedule validates on every model.
            assert!(mixed_schedule(&full).validate(&full).is_ok(), "{}", e.name);
        }
    }

    #[test]
    fn specs_parse_classes_and_reject_garbage() {
        assert_eq!(model("resnet18-cifar@10").unwrap().num_classes(), 10);
        assert_eq!(model("mlp").unwrap().num_classes(), 10);
        assert_eq!(model(" tiny@100 ").unwrap().name(), "tiny@100");
        assert!(model("resnet18-cifar@x").is_err());
        assert!(model("resnet18-cifar@1").is_err(), "degenerate class counts rejected");
        assert!(model("resnet18-cifar@9999").is_err());
        let err = model("bogus").unwrap_err();
        assert!(err.contains("unknown model") && err.contains("resnet18-cifar"), "{err}");
    }

    #[test]
    fn malformed_specs_each_get_their_own_rejection() {
        // Empty name — not "unknown model \"\"".
        let err = model("@100").unwrap_err();
        assert!(err.contains("empty model name"), "{err}");
        let err = model("").unwrap_err();
        assert!(err.contains("empty model name"), "{err}");
        let err = model("   ").unwrap_err();
        assert!(err.contains("empty model name"), "{err}");
        // Empty class count.
        let err = model("tiny@").unwrap_err();
        assert!(err.contains("empty class count"), "{err}");
        // Non-numeric class count.
        let err = model("tiny@ten").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
        // Trailing garbage after a numeric count.
        let err = model("tiny@100x").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
        let err = model("tiny@100 extra").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
        // Sign characters are garbage too (no silent "+100" acceptance).
        let err = model("tiny@+100").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
        // Zero classes — rejected at parse, not by the later range check.
        let err = model("tiny@0").unwrap_err();
        assert!(err.contains("must be ≥ 1"), "{err}");
        // A second '@' lands in the class count and is garbage there.
        let err = model("tiny@10@10").unwrap_err();
        assert!(err.contains("not a number"), "{err}");
        // Absurdly large counts overflow usize and report range, not panic.
        let err = model("tiny@99999999999999999999999999").unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn attn_tiny_is_a_deep_uniform_fc_stack() {
        let net = model("attn-tiny").unwrap();
        assert_eq!(net.len(), 23, "embed + 3×7 block GEMMs + classifier");
        assert_eq!(net.num_classes(), 100);
        assert!(
            net.layers().iter().all(|l| matches!(l.kind, LayerKind::Fc { .. })),
            "every layer is a GEMM"
        );
        assert!(
            net.layers().iter().all(|l| l.residual_from.is_none()),
            "no skip edges: every stage cut is valid"
        );
        // Deep-uniform: no single layer dominates, so pipeline stages can
        // balance. The embed GEMM (K = 3072) is the widest; it must still
        // be under half the total estimated work.
        let weights: Vec<usize> = net
            .layers()
            .iter()
            .map(|l| match &l.kind {
                LayerKind::Fc { k, n, .. } => k * n,
                _ => 0,
            })
            .collect();
        let total: usize = weights.iter().sum();
        let max = *weights.iter().max().unwrap();
        assert!(max * 2 < total, "one layer dominates: {max}/{total}");
    }

    #[test]
    fn class_count_changes_identity_but_not_backbone() {
        let a = model("resnet18-cifar@100").unwrap();
        let b = model("resnet18-cifar@10").unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.len(), b.len());
        // The spec round-trips through the graph name.
        assert_eq!(model(b.name()).unwrap().fingerprint(), b.fingerprint());
    }

    #[test]
    fn model_head_truncates_to_a_prefix() {
        let head = model_head("resnet34-cifar@10", 3).unwrap();
        assert_eq!(head.len(), 3);
        assert_eq!(head.name(), "resnet34-cifar@10");
        assert!(model_head("bogus", 3).is_err());
        assert!(model_head("tiny", 0).is_err(), "a 0-layer head is an error, not a clamp");
    }
}
