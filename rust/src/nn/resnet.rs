//! ResNet-18 (CIFAR variant) topology — the workload of paper Fig. 3.
//!
//! The CIFAR variant (He et al.'s original CIFAR adaptation of the
//! ImageNet-18 model): a 3×3 stem at 32×32, four stages of two basic blocks
//! each at widths 64/128/256/512 (stride-2 at each stage boundary with a
//! 1×1 projection shortcut), global average pooling, and a 100-way FC.
//!
//! Per the paper, the input (stem) and output layers stay in "full
//! precision"; the 20 quantized kernels of Fig. 3 are the 16 block convs,
//! the 3 projection shortcuts, and the final FC.

use crate::kernels::Conv2dParams;
use crate::nn::model::{Precision, PrecisionMap};

/// One convolution layer instance.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub name: String,
    pub params: Conv2dParams,
    /// ReLU after requant (always true except the FC).
    pub relu: bool,
    /// This conv closes a basic block: add the skip-connection input.
    pub residual: bool,
    /// Part of Fig. 3's quantized-layer set.
    pub quantized: bool,
}

/// Graph node.
#[derive(Clone, Debug)]
pub enum LayerKind {
    Conv(ConvLayer),
    /// Global average pool (h, w, c).
    AvgPool { h: usize, w: usize, c: usize },
    /// Fully connected (as 1×1 GEMM): in features, out features.
    Fc { k: usize, n: usize, name: String },
}

/// Layer plus the index of the feature map it consumes (supports skips).
#[derive(Clone, Debug)]
pub struct NetLayer {
    pub kind: LayerKind,
    /// Index (into the runner's feature-map list) of this layer's input.
    pub input: usize,
    /// Feature-map index of the residual source (for `residual` convs).
    pub residual_from: Option<usize>,
}

fn conv(name: &str, h: usize, w: usize, c_in: usize, c_out: usize, ksz: usize, stride: usize, quantized: bool, residual: bool) -> ConvLayer {
    ConvLayer {
        name: name.to_string(),
        params: Conv2dParams {
            h,
            w,
            c_in,
            c_out,
            kh: ksz,
            kw: ksz,
            stride,
            pad: if ksz == 3 { 1 } else { 0 },
        },
        relu: true,
        residual,
        quantized,
    }
}

/// Build a basic-block CIFAR ResNet graph with `blocks[stage]` blocks per
/// stage (widths 64/128/256/512). `[2, 2, 2, 2]` is ResNet-18,
/// `[3, 4, 6, 3]` ResNet-34. Feature-map indices: 0 is the network input;
/// each layer appends one output map.
pub fn resnet_cifar(blocks: &[usize; 4], num_classes: usize) -> Vec<NetLayer> {
    let mut layers: Vec<NetLayer> = Vec::new();
    let mut maps = 1usize; // map 0 = network input
    let add = |layers: &mut Vec<NetLayer>, kind: LayerKind, input: usize, residual_from: Option<usize>, maps: &mut usize| -> usize {
        layers.push(NetLayer { kind, input, residual_from });
        let out = *maps;
        *maps += 1;
        out
    };

    // Stem (full precision per the paper; runs as int8 here — see DESIGN.md).
    let stem = add(&mut layers, LayerKind::Conv(conv("stem", 32, 32, 3, 64, 3, 1, false, false)), 0, None, &mut maps);

    let widths = [64usize, 128, 256, 512];
    let mut hw = 32usize;
    let mut block_in = stem;
    let mut c_in = 64usize;
    let mut idx = 1usize;
    for (stage, &c_out) in widths.iter().enumerate() {
        for block in 0..blocks[stage] {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let out_hw = hw / stride;
            // Projection shortcut when shape changes.
            let shortcut = if stride != 1 || c_in != c_out {
                let name = format!("conv{idx}_ds_s{}b{}", stage + 1, block + 1);
                idx += 1;
                Some(add(
                    &mut layers,
                    LayerKind::Conv(ConvLayer {
                        name,
                        params: Conv2dParams {
                            h: hw,
                            w: hw,
                            c_in,
                            c_out,
                            kh: 1,
                            kw: 1,
                            stride,
                            pad: 0,
                        },
                        relu: false,
                        residual: false,
                        quantized: true,
                    }),
                    block_in,
                    None,
                    &mut maps,
                ))
            } else {
                None
            };
            let n1 = format!("conv{idx}_s{}b{}a", stage + 1, block + 1);
            idx += 1;
            let c1 = add(
                &mut layers,
                LayerKind::Conv(conv(&n1, hw, hw, c_in, c_out, 3, stride, true, false)),
                block_in,
                None,
                &mut maps,
            );
            let n2 = format!("conv{idx}_s{}b{}b", stage + 1, block + 1);
            idx += 1;
            let res_src = shortcut.unwrap_or(block_in);
            let c2 = add(
                &mut layers,
                LayerKind::Conv(conv(&n2, out_hw, out_hw, c_out, c_out, 3, 1, true, true)),
                c1,
                Some(res_src),
                &mut maps,
            );
            block_in = c2;
            c_in = c_out;
            hw = out_hw;
        }
    }
    let pooled = add(&mut layers, LayerKind::AvgPool { h: hw, w: hw, c: 512 }, block_in, None, &mut maps);
    add(
        &mut layers,
        LayerKind::Fc { k: 512, n: num_classes, name: "fc".to_string() },
        pooled,
        None,
        &mut maps,
    );
    layers
}

/// The ResNet-18 CIFAR graph — the paper's workload (Fig. 3).
pub fn resnet18_cifar(num_classes: usize) -> Vec<NetLayer> {
    resnet_cifar(&[2, 2, 2, 2], num_classes)
}

/// The deeper ResNet-34 CIFAR variant ([3, 4, 6, 3] basic blocks): same
/// widths and K-axis alignment as ResNet-18, ~2x the quantized work — a zoo
/// topology for multi-model serving, beyond the paper's single workload.
pub fn resnet34_cifar(num_classes: usize) -> Vec<NetLayer> {
    resnet_cifar(&[3, 4, 6, 3], num_classes)
}

/// SPEED-style (arXiv 2409.14017) layer-wise precision schedule for the
/// CIFAR ResNet-18: the accuracy-critical first-stage convolutions and the
/// final classifier run 8-bit, every other quantized layer runs 2-bit
/// bit-serial (Ottavi et al., arXiv 2010.04073, motivate the same split for
/// mixed-precision RISC-V cores). The unquantized stem is pinned to int8 by
/// [`PrecisionMap::resolve`] regardless. Works on truncated graphs too
/// (only layers present in `net` are overridden).
pub fn resnet18_mixed_schedule(net: &[NetLayer]) -> PrecisionMap {
    let mut map = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    for l in net {
        match &l.kind {
            LayerKind::Conv(c) if c.quantized && c.name.contains("_s1") => {
                map.set(&c.name, Precision::Int8);
            }
            LayerKind::Fc { name, .. } => map.set(name, Precision::Int8),
            _ => {}
        }
    }
    map
}

/// Names + parameters of the quantized layers (Fig. 3's x-axis).
pub fn quantized_layers(net: &[NetLayer]) -> Vec<(String, Conv2dParams)> {
    let mut out = Vec::new();
    for l in net {
        match &l.kind {
            LayerKind::Conv(c) if c.quantized => out.push((c.name.clone(), c.params)),
            LayerKind::Fc { k, n, name } => {
                out.push((name.clone(), crate::kernels::matmul::gemm_params(1, *k, *n)))
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_cifar_has_expected_structure() {
        let net = resnet18_cifar(100);
        let convs = net
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Conv(_)))
            .count();
        // 1 stem + 16 block convs + 3 projections = 20 convs.
        assert_eq!(convs, 20);
        // Fig. 3's quantized set: 16 + 3 + fc = 20 kernels.
        assert_eq!(quantized_layers(&net).len(), 20);
        // Spatial reduction: 32 → 4 before pooling.
        let pool = net.iter().find_map(|l| match l.kind {
            LayerKind::AvgPool { h, w, c } => Some((h, w, c)),
            _ => None,
        });
        assert_eq!(pool, Some((4, 4, 512)));
    }

    #[test]
    fn k_axes_are_64_aligned_for_bitserial() {
        // Every quantized conv needs K % 64 == 0 for word-aligned planes —
        // in both ResNet depths.
        for net in [resnet18_cifar(100), resnet34_cifar(100)] {
            for (name, p) in quantized_layers(&net) {
                assert_eq!(p.k() % 64, 0, "{name} K={}", p.k());
            }
        }
    }

    #[test]
    fn resnet34_cifar_has_expected_structure() {
        let net = resnet34_cifar(100);
        let convs = net.iter().filter(|l| matches!(l.kind, LayerKind::Conv(_))).count();
        // 1 stem + 32 block convs ([3,4,6,3] × 2) + 3 projections = 36.
        assert_eq!(convs, 36);
        // Quantized set: 32 + 3 + fc = 36 kernels.
        assert_eq!(quantized_layers(&net).len(), 36);
        // Same spatial schedule as ResNet-18: 32 → 4 before pooling.
        let pool = net.iter().find_map(|l| match l.kind {
            LayerKind::AvgPool { h, w, c } => Some((h, w, c)),
            _ => None,
        });
        assert_eq!(pool, Some((4, 4, 512)));
        // The mixed schedule applies unchanged (stage-1 names + classifier).
        let map = resnet18_mixed_schedule(&net);
        assert!(map.validate(&net).is_ok());
        assert_eq!(map.of("fc"), Precision::Int8);
        // 6 stage-1 convs + fc.
        assert_eq!(map.overrides().len(), 7);
    }

    #[test]
    fn mixed_schedule_splits_first_stage_and_classifier() {
        let net = resnet18_cifar(100);
        let map = resnet18_mixed_schedule(&net);
        assert!(!map.is_uniform());
        // 4 first-stage convs (no projection in stage 1) + fc.
        assert_eq!(map.overrides().len(), 5);
        assert_eq!(map.of("conv1_s1b1a"), Precision::Int8);
        assert_eq!(map.of("fc"), Precision::Int8);
        assert_eq!(
            map.of("conv11_s3b1a"),
            Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true }
        );
        assert!(map.validate(&net).is_ok());
    }

    #[test]
    fn residual_wiring_points_backwards() {
        let net = resnet18_cifar(100);
        for (i, l) in net.iter().enumerate() {
            if let Some(r) = l.residual_from {
                assert!(r <= i, "residual source {r} must precede layer {i}");
            }
            assert!(l.input <= i);
        }
    }
}
