//! # quark — reproduction of "Quark: An Integer RISC-V Vector Processor for
//! Sub-Byte Quantized DNN Inference" (AskariHemmat et al., 2023)
//!
//! The paper's testbed is RTL + a GF22FDX tape-out; this crate rebuilds the
//! whole system as software (see DESIGN.md for the substitution argument):
//!
//! * [`isa`] — RV64 scalar subset + RVV 1.0 subset + Quark's custom vector
//!   instructions (`vpopcnt`, `vshacc`, `vbitpack`), with encodings and an
//!   assembler.
//! * [`sim`] — cycle-approximate simulator of the CVA6 + Ara/Quark system:
//!   functional execution plus a structural timing model (lanes, VRF,
//!   chaining, AXI memory).
//! * [`arch`] — machine configurations (Ara-4L, Quark-4L, Quark-8L).
//! * [`quant`] — LSQ-style quantization math and bit-plane packing.
//! * [`kernels`] — the vector DNN runtime: bit-serial / int8 / fp32 conv2d and
//!   matmul, im2col, packing (with and without `vbitpack`), requantization.
//! * [`nn`] — model identity ([`nn::NetGraph`]) and the registry of named
//!   graphs ([`nn::zoo`]: ResNet-18/34 CIFAR, quarknet, mlp, tiny) executed
//!   on the runtime under uniform or mixed per-layer precision schedules
//!   ([`nn::model::PrecisionMap`]), with a naive-i128 host golden executor.
//! * [`program`] — the compile/execute split: [`program::compile`] turns
//!   (net, machine, schedule) into a relocatable
//!   [`program::CompiledProgram`] once; [`sim::Sim::execute`] replays it
//!   per request with zero kernel emission.
//! * [`cluster`] — tensor-parallel sharding: one inference partitioned
//!   across N simulated cores ([`cluster::compile_cluster`] →
//!   [`cluster::ClusterCores::infer`]), with a modeled inter-core
//!   activation all-gather ([`cluster::cluster_timing`]).
//! * [`phys`] — analytical area/power technology model + roofline analytics.
//! * [`runtime`] — PJRT golden-model loader (AOT HLO text from JAX).
//! * [`coordinator`] — multi-model batching inference server over a pool of
//!   simulated cores with golden-model cross-checking.
//! * [`obs`] — dual-clock observability: host request-lifecycle spans and
//!   simulated-cycle attribution (per-layer, per-micro-op-class), exported
//!   as Perfetto-loadable Chrome `trace_event` JSON and folded stacks.
//! * [`report`] — regenerates every table and figure of the paper.

pub mod arch;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod error;
pub mod isa;
pub mod kernels;
pub mod nn;
pub mod obs;
pub mod phys;
pub mod program;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
