//! Roofline analytics (paper Fig. 4).
//!
//! Performance is reported in effective GOPS (1 MAC = 2 ops, at the
//! *nominal* precision — a 1-bit MAC counts like any other, which is exactly
//! how sub-byte accelerators report their headline numbers and how the
//! paper's roofline compares Quark to Ara). Arithmetic intensity is
//! ops / DRAM-side bytes moved, both measured by the simulator.

use crate::arch::MachineConfig;
use crate::sim::Stats;

/// Machine roofline: compute ceiling + memory slope.
#[derive(Clone, Debug)]
pub struct Roofline {
    pub name: String,
    /// Peak effective GOPS at this precision.
    pub peak_gops: f64,
    /// Memory bandwidth, GB/s.
    pub mem_gbs: f64,
}

impl Roofline {
    /// Compute ceiling for a precision: `int8` → SEW=32 MAC rate;
    /// `(wbits, abits)` bit-serial → AND/popcount/acc triple rate divided by
    /// the plane-pair count; `fp32` → FPU MAC rate.
    pub fn for_machine(cfg: &MachineConfig, precision: &str) -> Roofline {
        let f = cfg.freq_ghz;
        let macs_per_cycle = match precision {
            "fp32" => {
                assert!(cfg.has_vfpu);
                cfg.elems_per_cycle(32)
            }
            "int8" => cfg.peak_int8_macs_per_cycle(),
            "w1a1" => cfg.peak_bitserial_macs_per_cycle(),
            "w2a2" => cfg.peak_bitserial_macs_per_cycle() / 4.0,
            "w2a1" | "w1a2" => cfg.peak_bitserial_macs_per_cycle() / 2.0,
            other => panic!("unknown precision {other}"),
        };
        Roofline {
            name: format!("{}-{}", cfg.name, precision),
            peak_gops: 2.0 * macs_per_cycle * f,
            mem_gbs: cfg.axi_bytes_per_cycle as f64 * f,
        }
    }

    /// Attainable GOPS at arithmetic intensity `ai` (ops/byte).
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.mem_gbs).min(self.peak_gops)
    }

    /// The ridge point (ops/byte) where the machine turns compute-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_gops / self.mem_gbs
    }
}

/// One measured kernel execution placed on the roofline.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    pub label: String,
    /// Arithmetic intensity, ops/byte.
    pub ai: f64,
    /// Achieved effective GOPS.
    pub gops: f64,
    /// Fraction of the attainable roof at this AI.
    pub efficiency: f64,
}

impl RooflinePoint {
    /// Build from simulator counters: `cycles` and per-kernel stats deltas.
    pub fn from_stats(label: impl Into<String>, roof: &Roofline, cfg: &MachineConfig, cycles: u64, stats: &Stats) -> RooflinePoint {
        let secs = cycles as f64 / (cfg.freq_ghz * 1e9);
        let ops = 2.0 * stats.effective_macs as f64;
        let gops = ops / secs / 1e9;
        let ai = stats.arithmetic_intensity();
        let att = roof.attainable(ai).max(1e-12);
        RooflinePoint { label: label.into(), ai, gops, efficiency: gops / att }
    }
}

/// Sampled roofline curve for plotting: `(ai, gops)` pairs, log-spaced.
pub fn roofline_curve(roof: &Roofline, ai_min: f64, ai_max: f64, n: usize) -> Vec<(f64, f64)> {
    (0..n)
        .map(|i| {
            let t = i as f64 / (n - 1) as f64;
            let ai = ai_min * (ai_max / ai_min).powf(t);
            (ai, roof.attainable(ai))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peaks_order_as_expected() {
        let ara = MachineConfig::ara(4);
        let q8 = MachineConfig::quark(8);
        let int8 = Roofline::for_machine(&ara, "int8");
        let w2 = Roofline::for_machine(&q8, "w2a2");
        let w1 = Roofline::for_machine(&q8, "w1a1");
        // Quark-8L at 2-bit should out-peak Ara-4L int8 (iso area/power).
        assert!(w2.peak_gops > int8.peak_gops, "{} vs {}", w2.peak_gops, int8.peak_gops);
        assert!(w1.peak_gops > 4.0 * w2.peak_gops * 0.9);
    }

    #[test]
    fn attainable_is_min_of_roofs() {
        let r = Roofline { name: "t".into(), peak_gops: 100.0, mem_gbs: 10.0 };
        assert!((r.attainable(1.0) - 10.0).abs() < 1e-9);
        assert!((r.attainable(1000.0) - 100.0).abs() < 1e-9);
        assert!((r.ridge() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn curve_is_monotone() {
        let r = Roofline { name: "t".into(), peak_gops: 100.0, mem_gbs: 10.0 };
        let c = roofline_curve(&r, 0.1, 100.0, 16);
        assert_eq!(c.len(), 16);
        for w in c.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9);
        }
    }
}
