//! Physical-implementation model + roofline analytics.
//!
//! The paper's Table II / Fig. 5 come from a GF22FDX synthesis + P&R flow
//! (Synopsys DC + Cadence Innovus) we obviously cannot run here. [`tech`] is
//! the substitution: an analytical area/power model whose *component*
//! constants are calibrated so the Ara-4-lane configuration matches the
//! published numbers, and whose *structure* (which components exist in which
//! machine) produces Quark's numbers — exposing *why* the integer lane is
//! ~2.3× smaller (the vector FPU and its operand queues are about half the
//! lane).
//!
//! [`roofline`] converts simulated cycle counts + memory traffic into the
//! GOPS-vs-arithmetic-intensity points of paper Fig. 4.

pub mod roofline;
pub mod tech;

pub use roofline::{roofline_curve, Roofline, RooflinePoint};
pub use tech::{PhysReport, TechModel};
