//! Analytical GF22FDX area/power model.
//!
//! Component constants (mm², mW at 1 GHz TT) are fitted to the paper's
//! Table II using the structural relations below; the *predictions* for all
//! three configurations are then checked against the table in the tests
//! (±6%). The model exposes the paper's two headline physical claims:
//!
//! * a Quark lane is ≈2.3× smaller than an Ara lane, because removing the
//!   vector FPU + its operand queues removes ~55% of the lane;
//! * a Quark lane consumes ≈1.9× less power for the same reason.
//!
//! Structure:
//! ```text
//! lane(L)      = VRF(4 KiB) + intDP + [bitserial] + [vFPU + fpOpQueues] + seq/L
//! die(L)       = L·lane(L) + CVA6 + uncore_fixed + L·uncore_per_lane(+fp)
//! lane_pwr(L)  = P_int + [P_bs] + [P_fpu] + P_seq/L      (at freq(L))
//! ```

use crate::arch::MachineConfig;

/// Fitted component constants. Public so ablation benches can perturb them.
#[derive(Clone, Debug)]
pub struct TechModel {
    /// 4 KiB of VRF SRAM+flops per lane, mm².
    pub a_vrf_4kib: f64,
    /// Integer datapath per lane (vALU + vMUL + int operand queues), mm².
    pub a_int_dp: f64,
    /// Quark bit-serial additions (popcount tree, shift-acc, bitpack slice).
    pub a_bitserial: f64,
    /// Vector FPU + FP operand queues per lane (Ara only), mm².
    pub a_vfpu: f64,
    /// Lane-amortized sequencer/control block, mm² (divided by lane count).
    pub a_seq_shared: f64,
    /// CVA6 + caches, mm².
    pub a_cva6: f64,
    /// Fixed uncore (AXI, dispatcher, SLDU/MASKU control), mm².
    pub a_uncore_fixed: f64,
    /// Uncore per lane (memory interface slice), mm².
    pub a_uncore_per_lane: f64,
    /// Extra uncore per lane for FP-capable routing (Ara), mm².
    pub a_uncore_fp_extra: f64,

    /// Per-lane integer power, mW at 1 GHz.
    pub p_int: f64,
    /// Bit-serial units, mW.
    pub p_bitserial: f64,
    /// Vector FPU + FP queues, mW.
    pub p_vfpu: f64,
    /// Shared sequencer power, mW (divided by lane count).
    pub p_seq_shared: f64,
}

impl Default for TechModel {
    fn default() -> Self {
        TechModel {
            // Fitted to Table II (see module docs for the equations).
            a_vrf_4kib: 0.0180,
            a_int_dp: 0.0195,
            a_bitserial: 0.0025,
            a_vfpu: 0.0710,
            a_seq_shared: 0.0400,
            a_cva6: 0.2500,
            a_uncore_fixed: 0.0000,
            a_uncore_per_lane: 0.0590,
            a_uncore_fp_extra: 0.0310,
            p_int: 81.7,
            p_bitserial: 3.0,
            p_vfpu: 113.0,
            p_seq_shared: 137.2,
        }
    }
}

/// Predicted physical numbers for one configuration (Table II row).
#[derive(Clone, Debug)]
pub struct PhysReport {
    pub name: String,
    pub lanes: usize,
    pub vrf_kib: usize,
    pub lane_area_mm2: f64,
    pub die_area_mm2: f64,
    pub freq_ghz: f64,
    pub lane_power_mw: f64,
    /// Per-lane area breakdown for Fig. 5: (component, mm²).
    pub breakdown: Vec<(&'static str, f64)>,
}

impl TechModel {
    /// Typical-corner frequency: both designs close at 1.05 GHz with 4 lanes;
    /// the 8-lane layout loses ~5% to interconnect (paper: 1.00 GHz).
    pub fn freq_ghz(&self, lanes: usize) -> f64 {
        if lanes <= 4 {
            1.05
        } else {
            1.05 - 0.05 * (lanes as f64 - 4.0) / 4.0
        }
    }

    /// Per-lane cell area for a machine.
    pub fn lane_area(&self, cfg: &MachineConfig) -> f64 {
        let mut a = self.a_vrf_4kib + self.a_int_dp + self.a_seq_shared / cfg.lanes as f64;
        if cfg.has_quark_isa {
            a += self.a_bitserial;
        }
        if cfg.has_vfpu {
            a += self.a_vfpu;
        }
        a
    }

    /// Die area.
    pub fn die_area(&self, cfg: &MachineConfig) -> f64 {
        let lanes = cfg.lanes as f64;
        let mut uncore = self.a_uncore_fixed + self.a_uncore_per_lane * lanes;
        if cfg.has_vfpu {
            uncore += self.a_uncore_fp_extra * lanes;
        }
        lanes * self.lane_area(cfg) + self.a_cva6 + uncore
    }

    /// Per-lane core power at the configuration's typical frequency, mW.
    pub fn lane_power(&self, cfg: &MachineConfig) -> f64 {
        let mut p = self.p_int + self.p_seq_shared / cfg.lanes as f64;
        if cfg.has_quark_isa {
            p += self.p_bitserial;
        }
        if cfg.has_vfpu {
            p += self.p_vfpu;
        }
        // Dynamic power scales ~linearly with frequency around 1 GHz.
        p * self.freq_ghz(cfg.lanes) / 1.05
    }

    /// Full report (one Table II column).
    pub fn report(&self, cfg: &MachineConfig) -> PhysReport {
        let mut breakdown = vec![
            ("VRF (4 KiB)", self.a_vrf_4kib),
            ("int datapath + opqueues", self.a_int_dp),
            ("sequencer (shared)", self.a_seq_shared / cfg.lanes as f64),
        ];
        if cfg.has_quark_isa {
            breakdown.push(("bit-serial units", self.a_bitserial));
        }
        if cfg.has_vfpu {
            breakdown.push(("vector FPU + FP opqueues", self.a_vfpu));
        }
        PhysReport {
            name: cfg.name.clone(),
            lanes: cfg.lanes,
            vrf_kib: cfg.vrf_kib(),
            lane_area_mm2: self.lane_area(cfg),
            die_area_mm2: self.die_area(cfg),
            freq_ghz: self.freq_ghz(cfg.lanes),
            lane_power_mw: self.lane_power(cfg),
            breakdown,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(got: f64, want: f64, tol: f64) -> bool {
        (got - want).abs() / want <= tol
    }

    #[test]
    fn table2_ara4_matches_paper() {
        let m = TechModel::default();
        let r = m.report(&MachineConfig::ara(4));
        assert!(close(r.lane_area_mm2, 0.120, 0.06), "lane {}", r.lane_area_mm2);
        assert!(close(r.die_area_mm2, 1.09, 0.06), "die {}", r.die_area_mm2);
        assert!(close(r.lane_power_mw, 229.0, 0.06), "power {}", r.lane_power_mw);
        assert!(close(r.freq_ghz, 1.05, 0.01));
    }

    #[test]
    fn table2_quark4_matches_paper() {
        let m = TechModel::default();
        let r = m.report(&MachineConfig::quark(4));
        assert!(close(r.lane_area_mm2, 0.051, 0.06), "lane {}", r.lane_area_mm2);
        assert!(close(r.die_area_mm2, 0.69, 0.06), "die {}", r.die_area_mm2);
        assert!(close(r.lane_power_mw, 119.0, 0.06), "power {}", r.lane_power_mw);
    }

    #[test]
    fn table2_quark8_matches_paper() {
        let m = TechModel::default();
        let r = m.report(&MachineConfig::quark(8));
        assert!(close(r.lane_area_mm2, 0.046, 0.06), "lane {}", r.lane_area_mm2);
        assert!(close(r.die_area_mm2, 1.09, 0.06), "die {}", r.die_area_mm2);
        assert!(close(r.lane_power_mw, 97.0, 0.06), "power {}", r.lane_power_mw);
        assert!(close(r.freq_ghz, 1.00, 0.01));
    }

    #[test]
    fn headline_ratios() {
        let m = TechModel::default();
        let ara = m.report(&MachineConfig::ara(4));
        let quark = m.report(&MachineConfig::quark(4));
        let area_ratio = ara.lane_area_mm2 / quark.lane_area_mm2;
        let power_ratio = ara.lane_power_mw / quark.lane_power_mw;
        // Paper: lanes 2.3× smaller (abstract says 2×, §IV says 2.3×), 1.9×
        // less power.
        assert!(area_ratio > 2.0 && area_ratio < 2.6, "area ratio {area_ratio}");
        assert!(power_ratio > 1.7 && power_ratio < 2.1, "power ratio {power_ratio}");
    }

    #[test]
    fn iso_budget_quark8_vs_ara4() {
        // Fig. 4's premise: Quark-8L fits the same die area and power budget
        // as Ara-4L.
        let m = TechModel::default();
        let ara = m.report(&MachineConfig::ara(4));
        let q8 = m.report(&MachineConfig::quark(8));
        assert!(close(q8.die_area_mm2, ara.die_area_mm2, 0.08));
        let ara_total_pwr = ara.lane_power_mw * 4.0;
        let q8_total_pwr = q8.lane_power_mw * 8.0;
        assert!(
            q8_total_pwr <= ara_total_pwr * 1.05,
            "Quark-8L power {q8_total_pwr} must fit Ara-4L budget {ara_total_pwr}"
        );
    }

    #[test]
    fn fpu_is_half_the_ara_lane() {
        // The removal argument: FPU + FP queues ≈ 55% of the Ara lane.
        let m = TechModel::default();
        let ara_lane = m.lane_area(&MachineConfig::ara(4));
        assert!(m.a_vfpu / ara_lane > 0.5);
    }
}
