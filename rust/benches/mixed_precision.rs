//! Bench: the schedule space the paper motivates — whole-network ResNet-18
//! cycles under uniform Int8, uniform Int2 (w2a2), and the SPEED-style
//! mixed per-layer schedule (first-stage convs + classifier at 8-bit),
//! all on the same simulated Quark-4L core.
//!
//! Plain `harness = false` binary (criterion is unavailable offline); prints
//! the per-layer table and asserts the headline property: the mixed
//! schedule's cycle count lands strictly between the uniform baselines.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rep = quark::report::mixed::generate_default();
    let elapsed = t0.elapsed();
    println!("{}", rep.markdown());
    let _ = quark::report::write_report("mixed.md", &rep.markdown());
    let _ = quark::report::write_report("mixed.csv", &rep.csv());

    println!("--- bench meta ---");
    println!(
        "mixed-schedule sweep wall time: {:.1}s (3 full-network simulations on {})",
        elapsed.as_secs_f64(),
        rep.machine
    );
    let (i8c, i2c, mxc) = (rep.int8_total, rep.int2_total, rep.mixed_total);
    println!("uniform int8 : {i8c:>12} cycles (1.00x)");
    println!("mixed        : {mxc:>12} cycles ({:.2}x vs int8)", i8c as f64 / mxc as f64);
    println!("uniform w2a2 : {i2c:>12} cycles ({:.2}x vs int8)", i8c as f64 / i2c as f64);
    assert!(
        i2c < mxc && mxc < i8c,
        "mixed schedule must land between the uniform baselines: {i2c} < {mxc} < {i8c}"
    );
}
