//! Bench: regenerates paper Table II (physical implementation) + Fig. 5
//! (area breakdown) from the analytical tech model, and runs the ablations
//! DESIGN.md calls out: what if the FPU stayed? what do 2/4/8/16 lanes cost?

use quark::arch::MachineConfig;
use quark::phys::TechModel;

fn main() {
    let reports = quark::report::table2::generate();
    println!("{}", quark::report::table2::markdown(&reports));
    println!("{}", quark::report::table2::fig5_markdown(&reports));
    let _ = quark::report::write_report("table2.md", &quark::report::table2::markdown(&reports));
    let _ = quark::report::write_report("fig5.md", &quark::report::table2::fig5_markdown(&reports));

    // Ablation 1: lane scaling (the paper's 4→8 lane step, extended).
    let m = TechModel::default();
    println!("## Ablation: Quark lane scaling\n");
    println!("| lanes | lane mm² | die mm² | GHz | power/lane mW | peak 1b-GOPS |");
    println!("|---|---|---|---|---|---|");
    for lanes in [2usize, 4, 8, 16] {
        let cfg = MachineConfig::quark(lanes);
        let r = m.report(&cfg);
        let gops = 2.0 * cfg.peak_bitserial_macs_per_cycle() * m.freq_ghz(lanes);
        println!(
            "| {lanes} | {:.3} | {:.2} | {:.2} | {:.0} | {:.0} |",
            r.lane_area_mm2, r.die_area_mm2, r.freq_ghz, r.lane_power_mw, gops
        );
    }

    // Ablation 2: keep the FPU but add the bit-serial units ("Ara++").
    println!("\n## Ablation: Ara + bit-serial units (keeping the vector FPU)\n");
    let ara = m.report(&MachineConfig::ara(4));
    let hybrid_lane = ara.lane_area_mm2 + m.a_bitserial;
    let quark = m.report(&MachineConfig::quark(4));
    println!(
        "hybrid lane = {:.3} mm² vs quark {:.3} mm² → dropping the FPU buys {:.1}% of the lane",
        hybrid_lane,
        quark.lane_area_mm2,
        100.0 * (hybrid_lane - quark.lane_area_mm2) / hybrid_lane
    );
}
