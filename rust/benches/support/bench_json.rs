//! Shared bench-result persistence: a tiny hand-rolled JSON writer (the
//! crate is dependency-free by policy).
//!
//! Each bench calls [`write`] with its row set; the result lands in
//! `BENCH_<name>.json` in the `cargo bench` working directory (the repo
//! root), committed per PR so the perf trajectory stays reviewable. Values
//! are produced by actually running the bench — the committed files are
//! snapshots of the most recent run, not targets.
//!
//! Included via `#[path]` from each bench binary; not every bench uses
//! every item.
#![allow(dead_code)]

use std::fmt::Write as _;

/// One labeled measurement row: ordered `(key, value)` pairs.
pub struct Row {
    label: String,
    fields: Vec<(&'static str, f64)>,
}

impl Row {
    pub fn new(label: &str) -> Self {
        Row { label: label.to_string(), fields: Vec::new() }
    }

    pub fn field(mut self, key: &'static str, value: f64) -> Self {
        self.fields.push((key, value));
        self
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// JSON number: Rust's `Display` for finite f64 is valid JSON; inf/NaN
/// (not representable) become `null`.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Write `BENCH_<bench>.json` with the given mode tag and rows. IO failure
/// only warns: persisting results must never fail the bench's acceptance
/// assertions (e.g. on a read-only checkout).
pub fn write(bench: &str, mode: &str, rows: &[Row]) {
    let mut out = String::new();
    out.push_str("{\n");
    let _ = writeln!(out, "  \"bench\": \"{}\",", esc(bench));
    let _ = writeln!(out, "  \"mode\": \"{}\",", esc(mode));
    out.push_str("  \"rows\": [\n");
    for (i, row) in rows.iter().enumerate() {
        let _ = write!(out, "    {{\"label\": \"{}\"", esc(row.label.as_str()));
        for (k, v) in &row.fields {
            let _ = write!(out, ", \"{}\": {}", esc(k), num(*v));
        }
        out.push_str(if i + 1 < rows.len() { "},\n" } else { "}\n" });
    }
    out.push_str("  ]\n}\n");
    let path = format!("BENCH_{bench}.json");
    match std::fs::write(&path, out) {
        Ok(()) => println!("(results written to {path})"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}
