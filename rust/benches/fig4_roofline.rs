//! Bench: regenerates paper Fig. 4 — conv2d 3×3 roofline, Quark-8L vs Ara-4L
//! (iso die area / power budget, Table II).

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let fig = quark::report::fig4::generate_default();
    let elapsed = t0.elapsed();
    println!("{}", fig.markdown());
    let _ = quark::report::write_report("fig4.md", &fig.markdown());
    let _ = quark::report::write_report("fig4.csv", &fig.csv());

    println!("--- bench meta ---");
    println!("fig4 regeneration wall time: {:.1}s", elapsed.as_secs_f64());
    let wins = fig.sweep.iter().all(|(_, q, a)| q > a);
    println!(
        "paper: Quark outperforms Ara at ALL input sizes | measured: {}",
        if wins { "yes" } else { "NO" }
    );
    assert!(wins);
}
