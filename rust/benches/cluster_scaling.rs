//! Bench: tensor-parallel strong scaling on the full ResNet-18 (CIFAR)
//! graph — modeled cluster latency at 1/2/4/8 shard cores, for w2a2, w1a1,
//! and the mixed schedule.
//!
//! Reuses the report generator ([`quark::report::cluster::generate`] — the
//! same sweep `repro cluster` runs) so the bench's acceptance math can
//! never drift from the published report: per (schedule, shard count) the
//! cluster model is `Σ_layers max(shard compute) + all-gather sync`
//! ([`quark::cluster`]), and the rows carry speedup vs the true 1-shard
//! run plus the Amdahl-style sync fraction.
//!
//! Acceptance: ≥1.6x modeled-latency speedup at 4 shards on ResNet-18
//! w2a2. Pass `--fast` for a truncated 8-layer graph (smoke only; the
//! assertion is calibrated to the full net and skipped).

#[path = "support/bench_json.rs"]
mod bench_json;

use std::time::Instant;

use quark::nn::zoo;
use quark::report::cluster::{generate, DEFAULT_SHARD_COUNTS};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let net = zoo::model_profile("resnet18-cifar@100", fast).expect("registry entry");

    println!(
        "== cluster strong scaling, ResNet-18{} at {:?} shard cores ==",
        if fast { " (truncated --fast graph)" } else { "" },
        DEFAULT_SHARD_COUNTS
    );
    let t0 = Instant::now();
    let rep = generate(&net, &DEFAULT_SHARD_COUNTS);
    let sweep_s = t0.elapsed().as_secs_f64();

    println!(
        "{:<10} {:>6} {:>14} {:>12} {:>9} {:>10} {:>11}",
        "schedule", "shards", "model cycles", "sync cycles", "speedup", "sync frac", "shard util"
    );
    for r in &rep.rows {
        println!(
            "{:<10} {:>6} {:>14} {:>12} {:>8.2}x {:>10.4} {:>11.2}",
            r.schedule,
            r.shards,
            r.total_cycles,
            r.sync_cycles,
            r.speedup,
            r.sync_fraction,
            r.mean_shard_util
        );
    }
    println!(
        "\n(model: per layer, max over shard cores of compute cycles, plus a ring\n\
         all-gather of the partial output channels charged vs axi_bytes_per_cycle;\n\
         im2col + activation packing replicates per shard — the serial fraction.\n\
         sweep host wall-clock: {sweep_s:.2} s, shard programs compiled + replayed\n\
         on parallel host threads)"
    );
    let rows: Vec<_> = rep
        .rows
        .iter()
        .map(|r| {
            bench_json::Row::new(&format!("{}_s{}", r.schedule, r.shards))
                .field("total_cycles", r.total_cycles as f64)
                .field("sync_cycles", r.sync_cycles as f64)
                .field("speedup", r.speedup)
                .field("sync_fraction", r.sync_fraction)
                .field("mean_shard_util", r.mean_shard_util)
        })
        .collect();
    bench_json::write("cluster_scaling", if fast { "fast" } else { "full" }, &rows);
    if !fast {
        let r = rep
            .rows
            .iter()
            .find(|r| r.schedule == "w2a2" && r.shards == 4)
            .expect("default sweep covers w2a2 at 4 shards");
        assert!(
            r.speedup >= 1.6,
            "acceptance: ≥1.6x modeled speedup at 4 shards on ResNet-18 w2a2 \
             (got {:.2}x, sync fraction {:.4})",
            r.speedup,
            r.sync_fraction
        );
        println!(
            "acceptance: {:.2}x ≥ 1.6x at 4 shards (w2a2), sync fraction {:.4} ✓",
            r.speedup, r.sync_fraction
        );
    }
}
