//! Bench: tensor-parallel strong scaling on the full ResNet-18 (CIFAR)
//! graph — modeled cluster latency at 1/2/4/8 shard cores, for w2a2, w1a1,
//! and the mixed schedule.
//!
//! Reuses the report generator ([`quark::report::cluster::generate`] — the
//! same sweep `repro cluster` runs) so the bench's acceptance math can
//! never drift from the published report: per (schedule, shard count) the
//! cluster model is `Σ_layers max(shard compute) + all-gather sync`
//! ([`quark::cluster`]), and the rows carry speedup vs the true 1-shard
//! run plus the Amdahl-style sync fraction.
//!
//! A second sweep compares the two parallelism axes on the deep uniform
//! workload tensor sharding handles worst: `attn-tiny`'s FC-only attention
//! stack ([`quark::report::cluster::generate_modes`]). Tensor sharding
//! replicates the per-request activation packing on every shard and pays an
//! all-gather per layer, so its sustained throughput is 1/latency; the
//! pipeline completes one request per `max(stage)` period once full.
//!
//! Acceptance: ≥1.6x modeled-latency speedup at 4 shards on ResNet-18
//! w2a2, and pipeline sustained throughput ≥1.5x tensor-parallel at
//! 4 cores on attn-tiny w2a2. Pass `--fast` for a truncated 8-layer
//! ResNet graph (smoke only; that assertion is calibrated to the full net
//! and skipped). The attn-tiny mode sweep always runs the full 23-layer
//! stack — it is cheap — so `--fast` still smokes the pipeline gate, at a
//! 1.2x floor (a de-pipelining regression drops the ratio to ~1.0).

#[path = "support/bench_json.rs"]
mod bench_json;

use std::time::Instant;

use quark::nn::zoo;
use quark::report::cluster::{generate, generate_modes, DEFAULT_SHARD_COUNTS};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let net = zoo::model_profile("resnet18-cifar@100", fast).expect("registry entry");

    println!(
        "== cluster strong scaling, ResNet-18{} at {:?} shard cores ==",
        if fast { " (truncated --fast graph)" } else { "" },
        DEFAULT_SHARD_COUNTS
    );
    let t0 = Instant::now();
    let rep = generate(&net, &DEFAULT_SHARD_COUNTS);
    let sweep_s = t0.elapsed().as_secs_f64();

    println!(
        "{:<10} {:>6} {:>14} {:>12} {:>9} {:>10} {:>11}",
        "schedule", "shards", "model cycles", "sync cycles", "speedup", "sync frac", "shard util"
    );
    for r in &rep.rows {
        println!(
            "{:<10} {:>6} {:>14} {:>12} {:>8.2}x {:>10.4} {:>11.2}",
            r.schedule,
            r.shards,
            r.total_cycles,
            r.sync_cycles,
            r.speedup,
            r.sync_fraction,
            r.mean_shard_util
        );
    }
    println!(
        "\n(model: per layer, max over shard cores of compute cycles, plus a ring\n\
         all-gather of the partial output channels charged vs axi_bytes_per_cycle;\n\
         im2col + activation packing replicates per shard — the serial fraction.\n\
         sweep host wall-clock: {sweep_s:.2} s, shard programs compiled + replayed\n\
         on parallel host threads)"
    );
    // Tensor vs pipeline on the deep uniform workload (full attn-tiny in
    // both modes — 23 small FC layers, cheap either way).
    let attn = zoo::model("attn-tiny").expect("registry entry");
    let mode_counts = [1usize, 2, 4];
    println!(
        "\n== tensor vs pipeline, {} at {mode_counts:?} cores ==",
        attn.name()
    );
    let t1 = Instant::now();
    let modes = generate_modes(&attn, &mode_counts);
    let modes_s = t1.elapsed().as_secs_f64();
    println!(
        "{:<10} {:>6} {:>14} {:>11} {:>12} {:>10} {:>10} {:>11}",
        "schedule", "cores", "tensor cycles", "pipe fill", "pipe period", "pipe hops", "sustained", "stage util"
    );
    for r in &modes.rows {
        println!(
            "{:<10} {:>6} {:>14} {:>11} {:>12} {:>10} {:>9.2}x {:>11.2}",
            r.schedule,
            r.cores,
            r.tensor_cycles,
            r.pipeline_fill,
            r.pipeline_period,
            r.pipeline_hops,
            r.sustained_ratio,
            r.mean_stage_util
        );
    }
    println!(
        "\n(sustained = tensor latency / pipeline period: requests completed per\n\
         cycle once the pipe is full, vs one tensor-parallel request at a time.\n\
         mode sweep host wall-clock: {modes_s:.2} s)"
    );
    let mut rows: Vec<_> = rep
        .rows
        .iter()
        .map(|r| {
            bench_json::Row::new(&format!("{}_s{}", r.schedule, r.shards))
                .field("total_cycles", r.total_cycles as f64)
                .field("sync_cycles", r.sync_cycles as f64)
                .field("speedup", r.speedup)
                .field("sync_fraction", r.sync_fraction)
                .field("mean_shard_util", r.mean_shard_util)
        })
        .collect();
    rows.extend(modes.rows.iter().map(|r| {
        bench_json::Row::new(&format!("modes_{}_c{}", r.schedule, r.cores))
            .field("tensor_cycles", r.tensor_cycles as f64)
            .field("pipeline_fill", r.pipeline_fill as f64)
            .field("pipeline_period", r.pipeline_period as f64)
            .field("pipeline_hops", r.pipeline_hops as f64)
            .field("sustained_ratio", r.sustained_ratio)
            .field("mean_stage_util", r.mean_stage_util)
    }));
    bench_json::write("cluster_scaling", if fast { "fast" } else { "full" }, &rows);
    // Pipeline gate: runs in both modes (the attn-tiny sweep is identical),
    // with a lower --fast floor so the smoke stays robust while still
    // catching a de-pipelining regression (ratio ~1.0).
    let gate = modes
        .rows
        .iter()
        .find(|r| r.schedule == "w2a2" && r.cores == 4)
        .expect("mode sweep covers w2a2 at 4 cores");
    let floor = if fast { 1.2 } else { 1.5 };
    assert!(
        gate.sustained_ratio >= floor,
        "acceptance: pipeline sustained throughput ≥{floor}x tensor at 4 cores on \
         attn-tiny w2a2 (got {:.2}x, period {} vs tensor {})",
        gate.sustained_ratio,
        gate.pipeline_period,
        gate.tensor_cycles
    );
    println!(
        "acceptance: pipeline sustains {:.2}x ≥ {floor}x tensor at 4 cores (attn-tiny w2a2) ✓",
        gate.sustained_ratio
    );
    if !fast {
        let r = rep
            .rows
            .iter()
            .find(|r| r.schedule == "w2a2" && r.shards == 4)
            .expect("default sweep covers w2a2 at 4 shards");
        assert!(
            r.speedup >= 1.6,
            "acceptance: ≥1.6x modeled speedup at 4 shards on ResNet-18 w2a2 \
             (got {:.2}x, sync fraction {:.4})",
            r.speedup,
            r.sync_fraction
        );
        println!(
            "acceptance: {:.2}x ≥ 1.6x at 4 shards (w2a2), sync fraction {:.4} ✓",
            r.speedup, r.sync_fraction
        );
    }
}
