//! Microbenchmarks: per-kernel simulated MAC/cycle at each precision and
//! shape, plus the *host-side* simulation throughput (instructions emitted
//! per second) — the L3 perf metric tracked in EXPERIMENTS.md §Perf.

use std::time::Instant;

use quark::arch::MachineConfig;
use quark::kernels::bitpack::setup_index_vector;
use quark::kernels::conv2d::{conv2d_bitserial, conv2d_f32, conv2d_int8};
use quark::kernels::requantize::RqBuf;
use quark::kernels::Conv2dParams;
use quark::quant::pack_weight_planes;
use quark::sim::{Sim, SimMode};

struct Row {
    label: String,
    cycles: u64,
    macs: u64,
    instrs: u64,
    wall: f64,
}

fn bench_conv(cfg: &MachineConfig, p: &Conv2dParams, precision: &str, mode: SimMode) -> Row {
    let mut sim = Sim::new(cfg.clone());
    sim.set_mode(mode);
    let idx = setup_index_vector(&mut sim);
    let (k, n) = (p.k(), p.c_out);
    let fm_in = sim.alloc((p.h * p.w * p.c_in * 4) as u64);
    let out = sim.alloc((p.out_h() * p.out_w() * n * 4) as u64);
    let before = sim.stats().clone();
    let c0 = sim.cycles();
    let t0 = Instant::now();
    let run = match precision {
        "fp32" => {
            let w = sim.alloc((k * n * 4) as u64);
            let b = sim.alloc((n * 4) as u64);
            conv2d_f32(&mut sim, p, fm_in, w, b, out, true, None)
        }
        "int8" => {
            let w = sim.alloc((k * n) as u64);
            let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
            conv2d_int8(&mut sim, p, fm_in, w, &rq, out, None)
        }
        other => {
            let (bits, vbp) = match other {
                "w1a1" => (1, true),
                "w2a2" => (2, true),
                "w2a2-novbp" => (2, false),
                _ => unreachable!(),
            };
            let wpk = pack_weight_planes(&vec![0u8; k * n], k, n, bits, quark::kernels::conv2d::bitserial_block(cfg.vlen_bits, n));
            let w = sim.alloc(wpk.byte_len() as u64);
            let rq = RqBuf::create(&mut sim, &vec![0.01; n], &vec![0.0; n], &vec![0.0; n], 255.0, 0.0);
            conv2d_bitserial(&mut sim, p, bits, fm_in, &wpk, w, &rq, out, None, vbp, idx)
        }
    };
    let wall = t0.elapsed().as_secs_f64();
    let stats = sim.stats().delta_since(&before);
    Row {
        label: format!("{} {} {}x{}x{}", cfg.name, precision, p.h, p.w, p.c_in),
        cycles: sim.cycles() - c0,
        macs: run.macs,
        instrs: stats.scalar_instrs + stats.vector_instrs + stats.vcfg_instrs,
        wall,
    }
}

fn main() {
    let shapes = [
        Conv2dParams { h: 8, w: 8, c_in: 64, c_out: 64, kh: 3, kw: 3, stride: 1, pad: 1 },
        Conv2dParams { h: 16, w: 16, c_in: 64, c_out: 64, kh: 3, kw: 3, stride: 1, pad: 1 },
        Conv2dParams { h: 8, w: 8, c_in: 256, c_out: 256, kh: 3, kw: 3, stride: 1, pad: 1 },
    ];
    let ara = MachineConfig::ara(4);
    let quark = MachineConfig::quark(4);
    println!(
        "{:<32} {:>12} {:>12} {:>9} {:>11} {:>10}",
        "kernel", "cycles", "eff. MACs", "MAC/cyc", "sim instrs", "Minstr/s"
    );
    let mut rows = Vec::new();
    for p in &shapes {
        for (cfg, prec) in [
            (&ara, "fp32"),
            (&ara, "int8"),
            (&quark, "w1a1"),
            (&quark, "w2a2"),
            (&quark, "w2a2-novbp"),
        ] {
            let r = bench_conv(cfg, p, prec, SimMode::TimingOnly);
            println!(
                "{:<32} {:>12} {:>12} {:>9.2} {:>11} {:>10.2}",
                r.label,
                r.cycles,
                r.macs,
                r.macs as f64 / r.cycles as f64,
                r.instrs,
                r.instrs as f64 / r.wall / 1e6
            );
            rows.push(r);
        }
        println!();
    }

    // Host-side throughput comparison Full vs TimingOnly (the §Perf metric).
    println!("--- host simulation throughput (Full vs TimingOnly) ---");
    let p = shapes[0];
    for mode in [SimMode::Full, SimMode::TimingOnly] {
        let r = bench_conv(&quark, &p, "w2a2", mode);
        println!(
            "{:?}: {:.2} Minstr/s ({:.2}s for {} instrs)",
            mode,
            r.instrs as f64 / r.wall / 1e6,
            r.wall,
            r.instrs
        );
    }
}
