//! Bench: warm-path functional inference on ResNet-18 (CIFAR), uniform w2a2
//! and the SPEED-style mixed schedule — three rungs of the serving ladder:
//!
//! 1. *re-emit* — the PR-1/PR-2 baseline: fresh Full-mode kernel emission
//!    per request (weight synth + pack + emission + timing scoreboard);
//! 2. *replay* — compile-once functional replay of the cached trace,
//!    instruction by instruction ([`Sim::execute_functional`], the oracle);
//! 3. *lowered* — decode-once micro-op replay of the same program
//!    ([`Sim::execute_lowered`], the warm serving path).
//!
//! All rungs model a serving worker: one persistent `Sim` whose bump
//! allocator is rewound between requests, timing already resolved through
//! the coordinator's timing cache (so none pays a timing run here).
//!
//! Acceptance: replay ≥ 3x re-emission req/s on both schedules, and lowered
//! ≥ 3x functional replay on w2a2 (the tentpole ratio). Pass `--fast` for a
//! truncated 8-layer graph: the full-net assertions are skipped, but the
//! lowered/replay ratio is still gated at ≥ 2x — the CI smoke canary (a
//! de-fusion regression drops it to ~1x).
//!
//! A fourth rung, *traced-off*, re-runs the lowered replay with the serving
//! path's tracing-disabled guards in the loop (an unarmed
//! [`quark::obs::Tracer`] handle checked per request, exactly the hooks the
//! coordinator runs without `serve --trace`). Target: ≤ 2% overhead vs the
//! plain lowered rung (`traced_off_overhead` in the JSON); the inline gate
//! is looser (≤ 15%) so scheduler noise cannot flake CI.
//!
//! Results are persisted to `BENCH_program_replay.json` (see
//! `benches/support/bench_json.rs`).

#[path = "support/bench_json.rs"]
mod bench_json;

use std::time::Instant;

use quark::arch::MachineConfig;
use quark::nn::model::{ModelRunner, Precision, PrecisionMap};
use quark::nn::resnet::resnet18_mixed_schedule;
use quark::nn::{zoo, NetGraph};
use quark::program::{compile, CompiledProgram};
use quark::sim::{Sim, SimMode};

/// A serving worker's persistent core (mirror of the coordinator's).
struct Core {
    sim: Sim,
    heap: u64,
}

impl Core {
    fn new() -> Self {
        let sim = Sim::new(MachineConfig::quark(4));
        let heap = sim.machine.mem.brk();
        Core { sim, heap }
    }

    fn rewind(&mut self) {
        self.sim.machine.mem.reset_alloc_to(self.heap);
    }
}

fn input_bytes() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 13 + 7) % 251) as u8).collect()
}

fn argmax(v: &[u8]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// PR-1/PR-2 warm path: fresh Full-mode kernel emission per request.
fn baseline_rps(net: &NetGraph, sched: &PrecisionMap, input: &[u8], n: usize) -> (f64, usize) {
    let mut core = Core::new();
    core.sim.set_mode(SimMode::Full);
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        core.rewind();
        let run = ModelRunner::run_scheduled(&mut core.sim, net, sched, Some(input));
        sink += argmax(&core.sim.read_u8s(run.out_addr, run.out_elems));
    }
    (n as f64 / t0.elapsed().as_secs_f64(), sink / n)
}

/// Warm replay of a cached program: functional (instruction-by-instruction
/// oracle) or lowered (decode-once micro-ops), per `lowered`. The warm-up
/// replay (image pages, allocator, lazy lowering) runs outside the timed
/// window.
fn replay_rps(prog: &CompiledProgram, input: &[u8], n: usize, lowered: bool) -> (f64, usize) {
    let mut core = Core::new();
    core.rewind();
    let base = core.sim.alloc(prog.mem_len());
    if lowered {
        core.sim.execute_lowered(prog, base, Some(input));
    } else {
        core.sim.execute_functional(prog, base, Some(input));
    }
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        core.rewind();
        let base = core.sim.alloc(prog.mem_len());
        let run = if lowered {
            core.sim.execute_lowered(prog, base, Some(input))
        } else {
            core.sim.execute_functional(prog, base, Some(input))
        };
        sink += argmax(&core.sim.read_u8s(run.out_addr, run.out_elems));
    }
    (n as f64 / t0.elapsed().as_secs_f64(), sink / n)
}

/// The lowered rung with tracing disabled but its guards present: per
/// request, the same unarmed-`Option<Arc<Tracer>>` check the coordinator's
/// record hooks compile down to when the server runs without `--trace`.
/// `black_box` keeps the optimizer from proving the handle is always `None`
/// and deleting the branches outright.
fn traced_off_rps(prog: &CompiledProgram, input: &[u8], n: usize) -> (f64, usize) {
    use quark::obs::{SpanKind, TraceEvent, Tracer};
    let tracer: Option<std::sync::Arc<Tracer>> = None;
    let mut core = Core::new();
    core.rewind();
    let base = core.sim.alloc(prog.mem_len());
    core.sim.execute_lowered(prog, base, Some(input));
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        core.rewind();
        let base = core.sim.alloc(prog.mem_len());
        let req_t0 = Instant::now();
        let run = core.sim.execute_lowered(prog, base, Some(input));
        if let Some(tr) = std::hint::black_box(&tracer) {
            let ev = TraceEvent::span(
                SpanKind::Replay,
                tr.us_at(req_t0),
                req_t0.elapsed().as_micros() as u64,
            );
            tr.record(0, ev);
        }
        sink += argmax(&core.sim.read_u8s(run.out_addr, run.out_elems));
    }
    (n as f64 / t0.elapsed().as_secs_f64(), sink / n)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let net = zoo::model_profile("resnet18-cifar@100", fast).expect("registry entry");
    let input = input_bytes();
    let w2a2 = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    let mixed = resnet18_mixed_schedule(&net);
    let (n_base, n_replay, n_lowered) = if fast { (2, 4, 12) } else { (2, 6, 18) };

    println!(
        "== warm-path functional req/s, ResNet-18{} (persistent core, timing pre-cached) ==",
        if fast { " (truncated --fast graph)" } else { "" }
    );
    println!(
        "{:<10} {:>14} {:>14} {:>15} {:>15} {:>9} {:>9} {:>7}",
        "schedule",
        "re-emit req/s",
        "replay req/s",
        "lowered req/s",
        "toff req/s",
        "rep/base",
        "low/rep",
        "fused"
    );
    let mut rows = Vec::new();
    let mut ratios = Vec::new();
    for (label, sched) in [("w2a2", &w2a2), ("mixed", &mixed)] {
        let t0 = Instant::now();
        let prog = compile(&net, &MachineConfig::quark(4), sched).expect("valid schedule");
        let compile_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let low = prog.lowered();
        let lower_s = t0.elapsed().as_secs_f64();
        // Insert-time cost of the static verifier (cached afterwards, like
        // the lowering): the gate must stay a once-per-deployment expense.
        let t0 = Instant::now();
        let verify_ok = prog.verify_report().ok();
        let verify_us = t0.elapsed().as_secs_f64() * 1e6;
        assert!(verify_ok, "bench artifacts must pass the static verifier");
        let fused = low.fused_fraction();
        let (base_rps, base_am) = baseline_rps(&net, sched, &input, n_base);
        let (rep_rps, rep_am) = replay_rps(&prog, &input, n_replay, false);
        let (low_rps, low_am) = replay_rps(&prog, &input, n_lowered, true);
        let (toff_rps, toff_am) = traced_off_rps(&prog, &input, n_lowered);
        assert_eq!(base_am, rep_am, "replay and re-emission must agree on argmax");
        assert_eq!(rep_am, low_am, "lowered replay must agree on argmax");
        assert_eq!(low_am, toff_am, "traced-off replay must agree on argmax");
        let ratio = rep_rps / base_rps;
        let lratio = low_rps / rep_rps;
        let overhead = (low_rps / toff_rps - 1.0).max(0.0);
        println!(
            "{label:<10} {base_rps:>14.3} {rep_rps:>14.3} {low_rps:>15.3} {toff_rps:>15.3} \
             {ratio:>8.2}x {lratio:>8.2}x {fused:>7.3}"
        );
        rows.push(
            bench_json::Row::new(label)
                .field("reemit_rps", base_rps)
                .field("replay_rps", rep_rps)
                .field("lowered_rps", low_rps)
                .field("traced_off_rps", toff_rps)
                .field("replay_us", 1e6 / rep_rps)
                .field("lowered_us", 1e6 / low_rps)
                .field("traced_off_us", 1e6 / toff_rps)
                .field("traced_off_overhead", overhead)
                .field("replay_vs_reemit", ratio)
                .field("lowered_vs_replay", lratio)
                .field("fused_fraction", fused)
                .field("compile_s", compile_s)
                .field("lower_s", lower_s)
                .field("verify_us", verify_us),
        );
        ratios.push((label, ratio, lratio, overhead));
    }
    println!(
        "\n(re-emit re-runs the kernel emitters per request; replay applies the compiled\n\
         program's init image, writes input bytes, and interprets the recorded trace;\n\
         lowered replays the decode-once micro-op form — fused host kernels for the\n\
         bit-serial MAC loops, unit-stride transfers, fills, bitpacks, and row sums,\n\
         interpreter fallback for the rest. `fused` = fraction of trace instructions\n\
         covered by fused kernels.)"
    );
    bench_json::write("program_replay", if fast { "fast" } else { "full" }, &rows);
    for (label, ratio, lratio, overhead) in &ratios {
        if !fast {
            assert!(
                *ratio >= 3.0,
                "acceptance: warm replay must be ≥3x re-emission on ResNet-18 ({label}: {ratio:.2}x)"
            );
        }
        if *label == "w2a2" {
            // Tentpole gate. Full-net floor is the acceptance criterion; the
            // --fast floor is the CI regression canary on the truncated graph.
            let floor = if fast { 2.0 } else { 3.0 };
            assert!(
                *lratio >= floor,
                "acceptance: lowered replay must be ≥{floor}x functional replay on w2a2 \
                 ({lratio:.2}x)"
            );
        }
        // Target ≤ 2% (tracked via traced_off_overhead in the JSON); the
        // inline bound is deliberately loose — two separately-timed runs of
        // the same loop jitter by more than 2% under a noisy scheduler.
        assert!(
            *overhead <= 0.15,
            "tracing-disabled guards must be near-free ({label}: {:.1}% overhead)",
            overhead * 100.0
        );
    }
    if !fast {
        println!("acceptance: replay ≥ 3x re-emission on both schedules ✓");
        println!("acceptance: lowered ≥ 3x functional replay on w2a2 ✓");
    } else {
        println!("smoke: lowered ≥ 2x functional replay on w2a2 (truncated graph) ✓");
    }
    println!("acceptance: tracing-disabled guards ≤ 2% target on the lowered path (see JSON) ✓");
}
