//! Bench: warm-path functional inference — cached `CompiledProgram` replay
//! vs the PR-1/PR-2 re-emit baseline on ResNet-18 (CIFAR), uniform w2a2 and
//! the SPEED-style mixed schedule.
//!
//! Both sides model a serving worker: one persistent `Sim` whose bump
//! allocator is rewound between requests, timing already resolved through
//! the coordinator's timing cache (so neither side pays a timing run here).
//! The *baseline* then re-runs the kernel emitters for every request
//! (synthesize + pack weights, emit every instruction, simulate in `Full`
//! mode with the timing scoreboard — exactly what `WorkerCore::infer` did
//! before the compile/execute split). The *replay* side compiles the
//! program once and, per request, writes input bytes, replays the trace
//! functionally, and reads the logits.
//!
//! Acceptance: replay ≥ 3x baseline req/s on both schedules. Pass `--fast`
//! to run on a truncated 8-layer graph (quick smoke; the ratio still
//! prints, the assertion is skipped since it is calibrated to the full
//! net).

use std::time::Instant;

use quark::arch::MachineConfig;
use quark::nn::model::{ModelRunner, Precision, PrecisionMap};
use quark::nn::resnet::resnet18_mixed_schedule;
use quark::nn::{zoo, NetGraph};
use quark::program::compile;
use quark::sim::{Sim, SimMode};

/// A serving worker's persistent core (mirror of the coordinator's).
struct Core {
    sim: Sim,
    heap: u64,
}

impl Core {
    fn new() -> Self {
        let sim = Sim::new(MachineConfig::quark(4));
        let heap = sim.machine.mem.brk();
        Core { sim, heap }
    }

    fn rewind(&mut self) {
        self.sim.machine.mem.reset_alloc_to(self.heap);
    }
}

fn input_bytes() -> Vec<u8> {
    (0..32 * 32 * 3).map(|i| ((i * 13 + 7) % 251) as u8).collect()
}

fn argmax(v: &[u8]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// PR-1/PR-2 warm path: fresh Full-mode kernel emission per request.
fn baseline_rps(net: &NetGraph, sched: &PrecisionMap, input: &[u8], n: usize) -> (f64, usize) {
    let mut core = Core::new();
    core.sim.set_mode(SimMode::Full);
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        core.rewind();
        let run = ModelRunner::run_scheduled(&mut core.sim, net, sched, Some(input));
        sink += argmax(&core.sim.read_u8s(run.out_addr, run.out_elems));
    }
    (n as f64 / t0.elapsed().as_secs_f64(), sink / n)
}

/// Compile-once warm path: functional replay of the cached program.
fn replay_rps(net: &NetGraph, sched: &PrecisionMap, input: &[u8], n: usize) -> (f64, usize, f64) {
    let t0 = Instant::now();
    let prog = compile(net, &MachineConfig::quark(4), sched).expect("valid schedule");
    let compile_s = t0.elapsed().as_secs_f64();
    let mut core = Core::new();
    // Warm-up replay (image pages, allocator) outside the timed window.
    core.rewind();
    let base = core.sim.alloc(prog.mem_len());
    core.sim.execute_functional(&prog, base, Some(input));
    let mut sink = 0usize;
    let t0 = Instant::now();
    for _ in 0..n {
        core.rewind();
        let base = core.sim.alloc(prog.mem_len());
        let run = core.sim.execute_functional(&prog, base, Some(input));
        sink += argmax(&core.sim.read_u8s(run.out_addr, run.out_elems));
    }
    (n as f64 / t0.elapsed().as_secs_f64(), sink / n, compile_s)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let net = zoo::model_profile("resnet18-cifar@100", fast).expect("registry entry");
    let input = input_bytes();
    let w2a2 = PrecisionMap::uniform(Precision::Sub { abits: 2, wbits: 2, use_vbitpack: true });
    let mixed = resnet18_mixed_schedule(&net);
    let (n_base, n_replay) = if fast { (2, 4) } else { (2, 6) };

    println!(
        "== warm-path functional req/s, ResNet-18{} (persistent core, timing pre-cached) ==",
        if fast { " (truncated --fast graph)" } else { "" }
    );
    println!(
        "{:<10} {:>14} {:>14} {:>10} {:>12}",
        "schedule", "re-emit req/s", "replay req/s", "ratio", "compile s"
    );
    let mut ratios = Vec::new();
    for (label, sched) in [("w2a2", &w2a2), ("mixed", &mixed)] {
        let (base_rps, base_am) = baseline_rps(&net, sched, &input, n_base);
        let (rep_rps, rep_am, compile_s) = replay_rps(&net, sched, &input, n_replay);
        assert_eq!(base_am, rep_am, "replay and re-emission must agree on argmax");
        let ratio = rep_rps / base_rps;
        println!("{label:<10} {base_rps:>14.3} {rep_rps:>14.3} {ratio:>9.2}x {compile_s:>12.3}");
        ratios.push((label, ratio));
    }
    println!(
        "\n(baseline re-runs the kernel emitters per request: weight synth + pack + emission\n\
         + timing scoreboard + functional execution; replay applies the compiled program's\n\
         init image, writes input bytes, and executes the recorded trace — values only)"
    );
    if !fast {
        for (label, ratio) in &ratios {
            assert!(
                *ratio >= 3.0,
                "acceptance: warm replay must be ≥3x re-emission on ResNet-18 ({label}: {ratio:.2}x)"
            );
        }
        println!("acceptance: replay ≥ 3x re-emission on both schedules ✓");
    }
}
