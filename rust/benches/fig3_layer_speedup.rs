//! Bench: regenerates paper Fig. 3 — per-layer ResNet-18 speedups of Quark
//! Int1 / Int2 (± vbitpack) over Ara Int8 (plus Ara FP32).
//!
//! Plain `harness = false` binary (criterion is unavailable offline); prints
//! the full figure and the wall-clock cost of regenerating it.

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let fig = quark::report::fig3::generate_default();
    let elapsed = t0.elapsed();
    println!("{}", fig.markdown());
    let _ = quark::report::write_report("fig3.md", &fig.markdown());
    let _ = quark::report::write_report("fig3.csv", &fig.csv());

    println!("--- bench meta ---");
    println!("fig3 regeneration wall time: {:.1}s (5 full-network simulations)", elapsed.as_secs_f64());
    // Paper targets for the record (conclusion §V): Int1 5.7x, Int2 3.5x.
    let (int1, _) = fig.mean_speedup(1);
    let (int2, _) = fig.mean_speedup(2);
    let (novbp, _) = fig.mean_speedup(3);
    println!("paper: Int1 5.7x | measured {int1:.2}x");
    println!("paper: Int2 3.5x | measured {int2:.2}x");
    println!("paper: Int2-no-vbitpack ≈ Int8 (\"not significant\") | measured {novbp:.2}x");
    assert!(fig.speedups(1).iter().all(|(_, s)| *s > 1.0), "Int1 must beat Int8 on every layer");
}
