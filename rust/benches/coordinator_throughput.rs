//! Bench: coordinator serving throughput/latency — worker-count and
//! batch-size sweeps, plus the headline comparison the serving overhaul is
//! about: repeated identical-shape requests served via the timing cache on
//! persistent cores vs the old per-request-`Sim` re-simulation baseline.

#[path = "support/bench_json.rs"]
mod bench_json;

use std::time::{Duration, Instant};

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};
use quark::nn::model::ModelRunner;
use quark::sim::{Sim, SimMode};

/// What the seed coordinator did for every request: construct a fresh `Sim`
/// and re-run the whole `TimingOnly` simulation. Workload taken from
/// `CoordinatorConfig::demo()` so both sides of the comparison stay coupled
/// if the demo deployment ever changes.
fn per_request_sim_baseline(n: u64) -> f64 {
    let cfg = CoordinatorConfig::demo();
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..n {
        let mut sim = Sim::new(cfg.machine.clone());
        sim.set_mode(SimMode::TimingOnly);
        let run = ModelRunner::run_scheduled(&mut sim, cfg.default_model(), &cfg.schedule, None);
        sink += run.reports.iter().map(|r| r.run.cycles).sum::<u64>();
    }
    assert!(sink > 0);
    n as f64 / t0.elapsed().as_secs_f64()
}

fn run(workers: usize, batch: usize, n: u64) -> (f64, f64, f64) {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = workers;
    cfg.batch_size = batch;
    cfg.batch_timeout = Duration::from_millis(5);
    cfg.max_queue = n as usize + 1;
    let coord = Coordinator::start(cfg);
    // Warm the timing cache so the sweep measures the steady state.
    coord
        .submit(InferenceRequest { id: u64::MAX, input: None, net: None, schedule: None, shards: None })
        .unwrap()
        .recv()
        .unwrap();
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| coord.submit(InferenceRequest { id, input: None, net: None, schedule: None, shards: None }).unwrap())
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let mut lat: Vec<f64> =
        responses.iter().map(|r| (r.queue_time + r.service_time).as_secs_f64() * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p99 = lat[((lat.len() as f64 * 0.99) as usize).min(lat.len() - 1)];
    coord.shutdown();
    (n as f64 / wall, p50, p99)
}

fn main() {
    println!("== timing-cache hit path vs seed per-request-Sim baseline ==");
    let baseline_rps = per_request_sim_baseline(8);
    let (warm_rps, p50, p99) = run(2, 4, 512);
    println!("per-request Sim baseline : {baseline_rps:>10.1} req/s");
    println!("cached coordinator (warm): {warm_rps:>10.1} req/s  (p50 {p50:.2} ms, p99 {p99:.2} ms)");
    println!("speedup                  : {:>10.1}x", warm_rps / baseline_rps);
    let mut rows = vec![bench_json::Row::new("warm_vs_baseline")
        .field("baseline_rps", baseline_rps)
        .field("warm_rps", warm_rps)
        .field("speedup", warm_rps / baseline_rps)
        .field("p50_ms", p50)
        .field("p99_ms", p99)];

    println!("\n== worker/batch sweep (warm cache, 128 requests each) ==");
    let n = 128u64;
    println!("{:>8} {:>6} {:>10} {:>10} {:>10}", "workers", "batch", "req/s", "p50 ms", "p99 ms");
    for workers in [1usize, 2, 4] {
        for batch in [1usize, 4, 16] {
            let (rps, p50, p99) = run(workers, batch, n);
            println!("{workers:>8} {batch:>6} {rps:>10.1} {p50:>10.2} {p99:>10.2}");
            rows.push(
                bench_json::Row::new(&format!("w{workers}_b{batch}"))
                    .field("rps", rps)
                    .field("p50_ms", p50)
                    .field("p99_ms", p99),
            );
        }
    }
    println!("\n(each request = one demo-net inference on a persistent simulated Quark-4L core;");
    println!(" timing resolved through the deterministic cache after the first batch)");
    bench_json::write("coordinator_throughput", "full", &rows);
}
