//! Bench: coordinator serving throughput/latency — worker-count and
//! batch-size sweeps, the headline comparison the serving overhaul is
//! about (repeated identical-shape requests served via the timing cache on
//! persistent cores vs the old per-request-`Sim` re-simulation baseline),
//! and the continuous-batching sweep: functional requests on a two-model
//! nano deployment at batch {1, 4, 16}, where a batch-B claim coalesces
//! into one multi-input lowered replay (one arena, one image application,
//! B micro-op passes).
//!
//! `--fast` runs a reduced version of every section — CI uses it as the
//! de-batching regression canary (the batch-16 vs batch-1 ratio assert
//! still fires, at a floor instead of the full-mode target).

#[path = "support/bench_json.rs"]
mod bench_json;

use std::sync::Arc;
use std::time::{Duration, Instant};

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};
use quark::nn::model::ModelRunner;
use quark::nn::{LayerKind, NetGraph, NetLayer};
use quark::sim::{Sim, SimMode};

/// What the seed coordinator did for every request: construct a fresh `Sim`
/// and re-run the whole `TimingOnly` simulation. Workload taken from
/// `CoordinatorConfig::demo()` so both sides of the comparison stay coupled
/// if the demo deployment ever changes.
fn per_request_sim_baseline(n: u64) -> f64 {
    let cfg = CoordinatorConfig::demo();
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..n {
        let mut sim = Sim::new(cfg.machine.clone());
        sim.set_mode(SimMode::TimingOnly);
        let run = ModelRunner::run_scheduled(&mut sim, cfg.default_model(), &cfg.schedule, None);
        sink += run.reports.iter().map(|r| r.run.cycles).sum::<u64>();
    }
    assert!(sink > 0);
    n as f64 / t0.elapsed().as_secs_f64()
}

fn run(workers: usize, batch: usize, n: u64) -> (f64, f64, f64) {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = workers;
    cfg.batch_size = batch;
    cfg.batch_timeout = Duration::from_millis(5);
    cfg.max_queue = n as usize + 1;
    let coord = Coordinator::start(cfg);
    // Warm the timing cache so the sweep measures the steady state.
    coord
        .submit(InferenceRequest { id: u64::MAX, ..Default::default() })
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| coord.submit(InferenceRequest { id, ..Default::default() }).unwrap())
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let mut lat: Vec<f64> =
        responses.iter().map(|r| (r.queue_time + r.service_time).as_secs_f64() * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p99 = lat[((lat.len() as f64 * 0.99) as usize).min(lat.len() - 1)];
    coord.shutdown();
    (n as f64 / wall, p50, p99)
}

/// A 1-layer FC net small enough that per-element compute is negligible
/// next to per-request serving overhead — the workload where continuous
/// batching's amortization (one claim, one arena image, one timing/program
/// resolution burst per group) shows up as wall-clock throughput.
fn nano_model(name: &str, k: usize) -> NetGraph {
    NetGraph::new(
        name,
        10,
        vec![NetLayer {
            kind: LayerKind::Fc { k, n: 10, name: "fc".into() },
            input: 0,
            residual_from: None,
        }],
    )
    .unwrap()
}

/// Sustained functional throughput on a warm two-model nano deployment at
/// the given max batch size. Requests alternate models in `batch`-sized
/// blocks, so every claim window holds same-DeployKey runs that coalesce
/// into one multi-input lowered replay.
fn run_batched(batch: usize, n: u64) -> f64 {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = 1;
    cfg.batch_size = batch;
    cfg.batch_timeout = Duration::from_millis(5);
    cfg.max_queue = n as usize + 1;
    cfg.models =
        vec![Arc::new(nano_model("nano-a@10", 64)), Arc::new(nano_model("nano-b@10", 128))];
    let coord = Coordinator::start(cfg);
    let models = ["nano-a@10", "nano-b@10"];
    // Warm both models' timing and program caches.
    for (i, name) in models.iter().enumerate() {
        coord
            .submit(InferenceRequest {
                id: u64::MAX - i as u64,
                input: Some(vec![1u8; 128]),
                net: Some(name.to_string()),
                ..Default::default()
            })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
    }
    let input = vec![42u8; 128];
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| {
            let name = models[(id as usize / batch) % 2];
            coord
                .submit(InferenceRequest {
                    id,
                    input: Some(input.clone()),
                    net: Some(name.to_string()),
                    ..Default::default()
                })
                .unwrap()
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    n as f64 / wall
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mode = if fast { "fast" } else { "full" };

    println!("== timing-cache hit path vs seed per-request-Sim baseline ==");
    let baseline_rps = per_request_sim_baseline(if fast { 2 } else { 8 });
    let (warm_rps, p50, p99) = run(2, 4, if fast { 128 } else { 512 });
    println!("per-request Sim baseline : {baseline_rps:>10.1} req/s");
    println!("cached coordinator (warm): {warm_rps:>10.1} req/s  (p50 {p50:.2} ms, p99 {p99:.2} ms)");
    println!("speedup                  : {:>10.1}x", warm_rps / baseline_rps);
    let mut rows = vec![bench_json::Row::new("warm_vs_baseline")
        .field("baseline_rps", baseline_rps)
        .field("warm_rps", warm_rps)
        .field("speedup", warm_rps / baseline_rps)
        .field("p50_ms", p50)
        .field("p99_ms", p99)];

    if !fast {
        println!("\n== worker/batch sweep (warm cache, 128 requests each) ==");
        let n = 128u64;
        println!("{:>8} {:>6} {:>10} {:>10} {:>10}", "workers", "batch", "req/s", "p50 ms", "p99 ms");
        for workers in [1usize, 2, 4] {
            for batch in [1usize, 4, 16] {
                let (rps, p50, p99) = run(workers, batch, n);
                println!("{workers:>8} {batch:>6} {rps:>10.1} {p50:>10.2} {p99:>10.2}");
                rows.push(
                    bench_json::Row::new(&format!("w{workers}_b{batch}"))
                        .field("rps", rps)
                        .field("p50_ms", p50)
                        .field("p99_ms", p99),
                );
            }
        }
    }

    println!("\n== continuous batching: functional requests, two-model nano deployment ==");
    let n = if fast { 192 } else { 512 } as u64;
    let mut batch_rps = Vec::new();
    println!("{:>6} {:>12}", "batch", "req/s");
    for batch in [1usize, 4, 16] {
        let rps = run_batched(batch, n);
        println!("{batch:>6} {rps:>12.1}");
        rows.push(bench_json::Row::new(&format!("batched_b{batch}")).field("rps", rps));
        batch_rps.push(rps);
    }
    let ratio = batch_rps[2] / batch_rps[0];
    rows.push(bench_json::Row::new("batch16_vs_batch1").field("ratio", ratio));
    println!("batch-16 vs batch-1 sustained: {ratio:.2}x");
    // De-batching regression canary: a coalesced batch-16 replay must beat
    // 16 single-request replays decisively. Full mode holds the acceptance
    // target; --fast (CI smoke, debug-friendly) holds a floor that still
    // catches a silently de-batched serve path.
    let floor = if fast { 1.5 } else { 3.0 };
    assert!(
        ratio >= floor,
        "continuous batching regressed: batch-16 sustained only {ratio:.2}x batch-1 (need >= {floor}x)"
    );

    println!("\n(each request = one inference on a persistent simulated Quark-4L core;");
    println!(" timing resolved through the deterministic cache after the first batch)");
    bench_json::write("coordinator_throughput", mode, &rows);
}
