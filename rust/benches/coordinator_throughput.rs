//! Bench: coordinator serving throughput/latency over worker-count and
//! batch-size sweeps (the L3 ablation DESIGN.md calls out: batching policy
//! and worker scaling).

use std::time::{Duration, Instant};

use quark::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};

fn run(workers: usize, batch: usize, n: u64) -> (f64, f64, f64) {
    let mut cfg = CoordinatorConfig::demo();
    cfg.workers = workers;
    cfg.batch_size = batch;
    cfg.batch_timeout = Duration::from_millis(5);
    let coord = Coordinator::start(cfg);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|id| coord.submit(InferenceRequest { id, input: vec![0u8; 32 * 32 * 3] }))
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let mut lat: Vec<f64> =
        responses.iter().map(|r| (r.queue_time + r.service_time).as_secs_f64() * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p99 = lat[(lat.len() as f64 * 0.99) as usize - 1];
    coord.shutdown();
    (n as f64 / wall, p50, p99)
}

fn main() {
    let n = 12u64;
    println!("{:>8} {:>6} {:>10} {:>10} {:>10}", "workers", "batch", "req/s", "p50 ms", "p99 ms");
    for workers in [1usize, 2, 4] {
        for batch in [1usize, 4] {
            let (rps, p50, p99) = run(workers, batch, n);
            println!("{workers:>8} {batch:>6} {rps:>10.2} {p50:>10.0} {p99:>10.0}");
        }
    }
    println!("\n(each request = one full demo-net inference simulated on a Quark-4L core)");
}
