"""AOT export: lower the L2/L1 computations once, write HLO **text**.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). Lowered with ``return_tuple=True``;
the Rust runtime unwraps with ``to_tuple``.

Artifacts (under ``artifacts/``):
  qgemm.hlo.txt   — bit-serial quantized GEMM (ACC, ASUM), M=8 K=128 N=16,
                    W2A2. The coordinator's golden cross-check target — its
                    shapes are mirrored in rust/src/coordinator/golden.rs.
  qconv.hlo.txt   — one quantized conv layer (ACC, ASUM), 8×8×64 → 64, 3×3.
  qnet.hlo.txt    — the small end-to-end quantized net (logits), weights
                    baked as constants (seed 0).

Usage: python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.bitserial import qgemm

# Cross-check shapes (mirrored in rust/src/coordinator/golden.rs).
QGEMM_M, QGEMM_K, QGEMM_N, QGEMM_BITS = 8, 128, 16, 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_qgemm() -> str:
    a = jax.ShapeDtypeStruct((QGEMM_M, QGEMM_K), jnp.int32)
    w = jax.ShapeDtypeStruct((QGEMM_K, QGEMM_N), jnp.int32)
    fn = lambda a, w: qgemm(a, w, QGEMM_BITS, QGEMM_BITS)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(a, w))


def lower_qconv() -> str:
    net = model.make_qnet(seed=0)
    conv = net.convs[0]._replace(stride=1)  # 16x16x64 → 64, full K=576
    x = jax.ShapeDtypeStruct((16, 16, 64), jnp.int32)
    fn = lambda x: model.qconv2d_acc(x, conv)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(x))


def lower_qnet() -> str:
    net = model.make_qnet(seed=0)
    x = jax.ShapeDtypeStruct((16, 16, 64), jnp.int32)
    fn = lambda x: (model.qnet_forward(net, x),)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(x))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="qgemm|qconv|qnet")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    jobs = {
        "qgemm": lower_qgemm,
        "qconv": lower_qconv,
        "qnet": lower_qnet,
    }
    if args.only:
        jobs = {args.only: jobs[args.only]}
    for name, fn in jobs.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
