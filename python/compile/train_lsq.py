"""Table I substitution: LSQ quantization-aware training at W/A ∈
{FP32, 8/8, 2/2, 1/1}.

The paper trains ResNet-18 on CIFAR-100 (a multi-GPU-hour job); neither the
dataset nor the compute exists in this environment, so per DESIGN.md we
reproduce the *shape* of Table I at reduced scale: a ResNet-style CNN trained
on a synthetic CIFAR-like task (32×32×3, 10 classes, class templates +
noise + random affine distortion — hard enough that capacity matters). The
qualitative result to reproduce: W1A1 loses significant accuracy, W2A2 is
within a point or two of FP32, W8A8 ≈ FP32.

First and last layers stay full precision, as in the paper.

Writes `artifacts/table1.tsv` (precision<TAB>accuracy), consumed by
`repro report table1`.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from .quantize import lsq_quantize


# ---------------------------------------------------------------------------
# Synthetic CIFAR-scale dataset.
# ---------------------------------------------------------------------------


def make_dataset(n_train=4096, n_test=1024, classes=10, seed=0):
    rng = np.random.default_rng(seed)
    templates = rng.normal(0, 1, (classes, 32, 32, 3)).astype(np.float32)
    # Smooth the templates so shifts matter (low-frequency class structure).
    for _ in range(2):
        templates = (
            templates
            + np.roll(templates, 1, 1)
            + np.roll(templates, -1, 1)
            + np.roll(templates, 1, 2)
            + np.roll(templates, -1, 2)
        ) / 5.0

    def sample(n, rng):
        y = rng.integers(0, classes, n)
        x = templates[y]
        # Random shift ±3 px + per-sample gain + strong noise.
        for i in range(n):
            x[i] = np.roll(x[i], rng.integers(-3, 4), axis=0)
            x[i] = np.roll(x[i], rng.integers(-3, 4), axis=1)
        gain = rng.uniform(0.7, 1.3, (n, 1, 1, 1)).astype(np.float32)
        noise = rng.normal(0, 0.6, x.shape).astype(np.float32)
        return (x * gain + noise).astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train, rng)
    xte, yte = sample(n_test, rng)
    return (jnp.asarray(xtr), jnp.asarray(ytr)), (jnp.asarray(xte), jnp.asarray(yte))


# ---------------------------------------------------------------------------
# ResNet-style model with LSQ fake-quantization.
# ---------------------------------------------------------------------------

WIDTHS = (16, 32, 64)


def init_params(key, classes=10):
    params = {}
    keys = jax.random.split(key, 16)
    ki = iter(keys)

    def conv_init(k, kh, kw, cin, cout):
        fan = kh * kw * cin
        return jax.random.normal(k, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan)

    params["stem"] = conv_init(next(ki), 3, 3, 3, WIDTHS[0])
    for s, w in enumerate(WIDTHS):
        cin = WIDTHS[max(s - 1, 0)]
        params[f"conv{s}a"] = conv_init(next(ki), 3, 3, cin, w)
        params[f"conv{s}b"] = conv_init(next(ki), 3, 3, w, w)
        if cin != w:
            params[f"proj{s}"] = conv_init(next(ki), 1, 1, cin, w)
    params["fc"] = jax.random.normal(next(ki), (WIDTHS[-1], classes)) * 0.01
    # One LSQ step per quantized tensor. Init per the LSQ paper's heuristic
    # (s0 ≈ 2·E|x|/√qp): weights are He-init (E|w| ≈ 0.03–0.08), activations
    # post-BN-ReLU (E|a| ≈ 0.4).
    steps = {}
    for s in range(len(WIDTHS)):
        for ab in "ab":
            steps[f"w_{s}{ab}"] = jnp.asarray(0.05)
            steps[f"a_{s}{ab}"] = jnp.asarray(0.5)
        steps[f"w_proj{s}"] = jnp.asarray(0.05)
    params["steps"] = steps
    return params


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def bn(x):
    """Parameter-free batch standardization (BN without affine): stabilizes
    the no-normalization net the way folded BN does at inference."""
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5)


def forward(params, x, bits: int):
    """bits=0 → FP32; otherwise W/A at `bits` (stem + fc stay FP32)."""
    steps = params["steps"]

    def qw(w, name):
        if bits == 0:
            return w
        return lsq_quantize(w, steps[name], bits, signed=True)

    def qa(a, name):
        if bits == 0:
            return a
        return lsq_quantize(a, steps[name], bits, signed=False)

    h = jax.nn.relu(bn(conv(x, params["stem"])))
    for s, width in enumerate(WIDTHS):
        stride = 1 if s == 0 else 2
        inp = h
        h = jax.nn.relu(bn(conv(qa(h, f"a_{s}a"), qw(params[f"conv{s}a"], f"w_{s}a"), stride)))
        h = bn(conv(qa(h, f"a_{s}b"), qw(params[f"conv{s}b"], f"w_{s}b")))
        if f"proj{s}" in params:
            inp = conv(inp, qw(params[f"proj{s}"], f"w_proj{s}"), stride)
        h = jax.nn.relu(h + inp)
    pooled = jnp.mean(h, axis=(1, 2))
    return pooled @ params["fc"]


def loss_fn(params, x, y, bits):
    logits = forward(params, x, bits)
    onehot = jax.nn.one_hot(y, logits.shape[-1])
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))


def train(bits: int, steps: int, seed=0, batch=64, lr=0.02, log=print):
    (xtr, ytr), (xte, yte) = make_dataset(seed=seed)
    params = init_params(jax.random.PRNGKey(seed))
    # Plain SGD with momentum (no optax in this environment).
    momentum = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(params, momentum, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, bits)
        momentum = jax.tree.map(lambda m, g: 0.9 * m + g, momentum, grads)
        params = jax.tree.map(lambda p, m: p - lr * m, params, momentum)
        return params, momentum, loss

    @jax.jit
    def accuracy(params, x, y):
        logits = forward(params, x, bits)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    rng = np.random.default_rng(seed)
    n = xtr.shape[0]
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        params, momentum, loss = step_fn(params, momentum, xtr[idx], ytr[idx])
        if (i + 1) % max(1, steps // 5) == 0:
            log(f"  [bits={bits}] step {i + 1}/{steps} loss {float(loss):.3f}")
    acc = float(accuracy(params, xte, yte)) * 100.0
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="../artifacts/table1.tsv")
    args = ap.parse_args()
    rows = []
    for label, bits in [("fp32", 0), ("w8a8", 8), ("w2a2", 2), ("w1a1", 1)]:
        print(f"training {label} ({args.steps} steps)…")
        acc = train(bits, args.steps)
        print(f"  {label}: {acc:.2f}%")
        rows.append((label, acc))
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("# precision\taccuracy (synthetic CIFAR-scale task — see DESIGN.md)\n")
        for label, acc in rows:
            f.write(f"{label}\t{acc:.2f}\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
