"""L2 — quantized model forward passes in JAX, calling the L1 kernel.

Mirrors the computation graph of paper Fig. 2: integer conv/linear via the
bit-serial kernel (`kernels.bitserial.qgemm`), followed by the full-precision
re-scale + clip + round (the step Quark keeps on the CVA6 scalar FPU), layer
after layer. All tensors on the integer path are unsigned codes (int32 here;
u8 in the Rust runtime).

Python never runs at inference time: `aot.py` lowers these functions once to
HLO text and the Rust runtime executes them through PJRT as the golden model.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.bitserial import qgemm
from .quantize import quantize_weights_unsigned, requantize


def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """NHWC im2col: x [H, W, C] → patches [OH*OW, kh*kw*C] (zero-padded).

    Patch element order is (kh, kw, c) — identical to the Rust kernels'
    patch layout, so K-dim indices line up across the stack.
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    idx_y = (jnp.arange(oh) * stride)[:, None] + jnp.arange(kh)[None, :]  # [OH, KH]
    idx_x = (jnp.arange(ow) * stride)[:, None] + jnp.arange(kw)[None, :]  # [OW, KW]
    # Gather [OH, KH, OW, KW, C] then reorder to [OH, OW, KH, KW, C].
    patches = xp[idx_y][:, :, idx_x]  # [OH, KH, OW, KW, C]
    patches = patches.transpose(0, 2, 1, 3, 4)
    return patches.reshape(oh * ow, kh * kw * c), oh, ow


class QConvParams(NamedTuple):
    """One quantized conv layer (codes + folded scales)."""

    w_codes: jax.Array  # int32 [K, N]
    w_alpha: float
    w_beta: float
    bias: jax.Array  # f32 [N]
    kh: int
    kw: int
    stride: int
    pad: int
    abits: int
    wbits: int
    out_bits: int


def qconv2d(x_codes, act_scale, p: QConvParams, out_scale):
    """Quantized conv: integer ACC/ASUM via the Pallas kernel, then the
    scalar-style requant. Returns (codes int32 [OH, OW, N], out_scale)."""
    patches, oh, ow = im2col(x_codes, p.kh, p.kw, p.stride, p.pad)
    acc, asum = qgemm(patches, p.w_codes, p.abits, p.wbits)
    out = requantize(
        acc, asum[:, None], act_scale, p.w_alpha, p.w_beta, p.bias[None, :], out_scale, p.out_bits
    )
    n = p.w_codes.shape[1]
    return out.reshape(oh, ow, n)


def qconv2d_acc(x_codes, p: QConvParams):
    """The pre-requant integer result (ACC, ASUM) — what the Rust coordinator
    cross-checks against the simulated `vand`/`vpopcnt`/`vshacc` pipeline."""
    patches, _, _ = im2col(x_codes, p.kh, p.kw, p.stride, p.pad)
    return qgemm(patches, p.w_codes, p.abits, p.wbits)


# ---------------------------------------------------------------------------
# A small end-to-end quantized network (the AOT e2e artifact).
# ---------------------------------------------------------------------------


class QNet(NamedTuple):
    convs: tuple
    act_scales: tuple  # input scale per conv
    out_scales: tuple
    fc_w: jax.Array  # int32 [C, classes]
    fc_alpha: float
    fc_beta: float
    fc_in_scale: float


def make_qnet(seed: int = 0, abits: int = 2, wbits: int = 2, classes: int = 10) -> QNet:
    """3 quantized convs (64→64→128 with stride-2 downsampling from 16×16)
    + GAP + quantized FC. Weights are seeded random floats quantized with the
    same affine scheme the Rust side uses."""
    rng = np.random.default_rng(seed)
    convs = []
    shapes = [
        (16, 64, 64, 3, 1),  # (hw_in, cin, cout, ksize, stride)
        (16, 64, 128, 3, 2),
        (8, 128, 128, 3, 1),
    ]
    for _, cin, cout, ksz, stride in shapes:
        k = ksz * ksz * cin
        wf = rng.normal(0, 0.1, (k, cout)).astype(np.float32)
        codes, alpha, beta = quantize_weights_unsigned(jnp.asarray(wf), wbits)
        convs.append(
            QConvParams(
                w_codes=codes,
                w_alpha=float(alpha),
                w_beta=float(beta),
                bias=jnp.asarray(rng.normal(0, 0.01, cout).astype(np.float32)),
                kh=ksz,
                kw=ksz,
                stride=stride,
                pad=1,
                abits=abits,
                wbits=wbits,
                out_bits=abits,
            )
        )
    fcf = rng.normal(0, 0.1, (128, classes)).astype(np.float32)
    fc_codes, fc_alpha, fc_beta = quantize_weights_unsigned(jnp.asarray(fcf), wbits)
    return QNet(
        convs=tuple(convs),
        act_scales=(0.05, 0.05, 0.05),
        out_scales=(0.05, 0.05, 0.05),
        fc_w=fc_codes,
        fc_alpha=float(fc_alpha),
        fc_beta=float(fc_beta),
        fc_in_scale=0.05,
    )


def qnet_forward(net: QNet, x_codes):
    """x_codes: int32 [16, 16, 64] activation codes → f32 logits [classes]."""
    x = x_codes
    for conv, s_in, s_out in zip(net.convs, net.act_scales, net.out_scales):
        x = qconv2d(x, s_in, conv, s_out)
    # Global average pool in the integer domain (sum; the 1/HW folds into
    # the FC input scale like the Rust avgpool's requant).
    h, w, c = x.shape
    pooled = jnp.sum(x.reshape(h * w, c), axis=0) // (h * w)
    acc, asum = qgemm(pooled[None, :], net.fc_w, net.convs[0].abits, net.convs[0].wbits)
    logits = net.fc_in_scale * (
        net.fc_alpha * acc[0].astype(jnp.float32) + net.fc_beta * asum[0].astype(jnp.float32)
    )
    return logits


# ---------------------------------------------------------------------------
# Float reference for the quantized conv (sanity: codes → reals agreement).
# ---------------------------------------------------------------------------


def qconv2d_float_ref(x_codes, act_scale, p: QConvParams):
    """Dequantize codes and convolve in f32 — the real-valued function the
    integer pipeline approximates. Used by tests to bound the requant error."""
    patches, oh, ow = im2col(x_codes, p.kh, p.kw, p.stride, p.pad)
    a_real = act_scale * patches.astype(jnp.float32)
    w_real = p.w_alpha * p.w_codes.astype(jnp.float32) + p.w_beta
    out = a_real @ w_real + p.bias[None, :]
    return out.reshape(oh, ow, -1)


@functools.partial(jax.jit, static_argnames=("abits", "wbits"))
def qgemm_with_asum(a_codes, w_codes, abits: int, wbits: int):
    """The artifact entry point for the Rust cross-check."""
    return qgemm(a_codes, w_codes, abits, wbits)
