"""L1 — the bit-serial sub-byte GEMM as a Pallas kernel.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): Quark implements
paper Eq. (1) with per-lane `vand`/`vpopcnt`/`vshacc` over 64-bit VRF words;
a TPU has no per-lane popcount and wants dense tiles in VMEM, so the same
insight — replace an m×n-bit multiply by AND+popcount over bit planes —
is re-expressed as:

* activations and weights are *bit-plane packed* into uint32 words (the
  bit-stream format `vbitpack` produces in hardware; here packing is a few
  reshape/shift ops in the surrounding jax function),
* the kernel tiles the output (BlockSpec over [bm, bn] tiles, the full packed
  K dimension resident per tile — the VMEM analogue of Quark's weights-
  resident VRF schedule),
* AND + a SWAR popcount (no native popcount op in XLA:CPU → the classic
  bit-twiddling reduction, fully vectorizable on the VPU) + shift-accumulate
  over the ≤4 plane pairs.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO which both jax and the Rust
runtime's PJRT client execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def popcount32(x):
    """SWAR popcount of a uint32 tensor (Hacker's Delight 5-2)."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def pack_rows(codes, bits: int):
    """Pack unsigned codes row-wise into bit planes.

    codes: int32 [R, K] → uint32 [bits, R, ceil(K/32)], little-endian bits
    (bit k%32 of word k//32 = bit p of codes[r, k]) — the jnp mirror of the
    hardware `vbitpack` layout and of rust `pack_bit_planes`.
    """
    r, k = codes.shape
    kw = -(-k // 32)
    padded = jnp.zeros((r, kw * 32), jnp.uint32).at[:, :k].set(codes.astype(jnp.uint32))
    lanes = padded.reshape(r, kw, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    planes = [
        jnp.sum(((lanes >> jnp.uint32(p)) & jnp.uint32(1)) * weights, axis=2, dtype=jnp.uint32)
        for p in range(bits)
    ]
    return jnp.stack(planes)  # [bits, R, KW]


def _qgemm_kernel(a_ref, w_ref, o_ref, *, abits: int, wbits: int):
    """One [bm, bn] output tile: Σ_p Σ_q 2^(p+q) Σ_kw popcount(a & w)."""
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for p in range(abits):
        a = a_ref[p]  # [bm, KW] uint32
        for q in range(wbits):
            w = w_ref[q]  # [KW, bn] uint32
            anded = a[:, :, None] & w[None, :, :]  # [bm, KW, bn]
            pc = popcount32(anded).astype(jnp.int32)
            part = jnp.sum(pc, axis=1)  # [bm, bn]
            acc = acc + (part << (p + q))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("abits", "wbits", "bm", "bn"))
def qgemm_bitserial(a_codes, w_codes, abits: int, wbits: int, bm: int = 8, bn: int = 64):
    """Bit-serial integer GEMM: ACC[M,N] = a_codes[M,K] @ w_codes[K,N].

    Inputs are unsigned codes (int32, values < 2**bits). Exact integer result,
    identical to `ref.qgemm_ref`'s ACC.
    """
    m, k = a_codes.shape
    k2, n = w_codes.shape
    assert k == k2
    a_planes = pack_rows(a_codes, abits)  # [abits, M, KW]
    w_planes = pack_rows(w_codes.T, wbits).transpose(0, 2, 1)  # [wbits, KW, N]
    kw = a_planes.shape[2]

    bm = min(bm, m)
    bn = min(bn, n)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    a_planes = jnp.pad(a_planes, ((0, 0), (0, mp - m), (0, 0)))
    w_planes = jnp.pad(w_planes, ((0, 0), (0, 0), (0, np_ - n)))

    acc = pl.pallas_call(
        functools.partial(_qgemm_kernel, abits=abits, wbits=wbits),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((abits, bm, kw), lambda i, j: (0, i, 0)),
            pl.BlockSpec((wbits, kw, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(a_planes, w_planes)
    return acc[:m, :n]


def qgemm(a_codes, w_codes, abits: int, wbits: int):
    """The L2-facing op: (ACC, ASUM) — everything the requant step needs."""
    acc = qgemm_bitserial(a_codes, w_codes, abits, wbits)
    asum = jnp.sum(a_codes.astype(jnp.int32), axis=1)
    return acc, asum
