"""Pure-jnp oracles for the bit-serial kernels.

These are the CORE correctness signal: the Pallas kernel (bitserial.py) must
match them exactly (integer arithmetic — `assert_array_equal`, not allclose),
and the Rust simulator's `vand`/`vpopcnt`/`vshacc` pipeline is cross-checked
against the same numbers through the AOT artifact.
"""

from __future__ import annotations

import jax.numpy as jnp


def qgemm_ref(a_codes, w_codes):
    """Integer GEMM over unsigned codes.

    a_codes: int32 [M, K] (values < 2**abits)
    w_codes: int32 [K, N] (values < 2**wbits)
    Returns (acc int32 [M, N], asum int32 [M]).
    """
    acc = jnp.matmul(a_codes.astype(jnp.int32), w_codes.astype(jnp.int32))
    asum = jnp.sum(a_codes.astype(jnp.int32), axis=1)
    return acc, asum


def pack_planes_ref(codes, bits: int):
    """Bit-plane packing oracle (mirrors rust `pack_bit_planes`).

    codes: int32 [K] → uint32 planes [bits, ceil(K/32)] little-endian bits.
    (32-bit words here: jnp has no uint64 enabled by default.)
    """
    k = codes.shape[0]
    kw = -(-k // 32)
    padded = jnp.zeros((kw * 32,), jnp.uint32).at[:k].set(codes.astype(jnp.uint32))
    lanes = padded.reshape(kw, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    planes = []
    for p in range(bits):
        bitsp = (lanes >> jnp.uint32(p)) & jnp.uint32(1)
        planes.append(jnp.sum(bitsp * weights, axis=1, dtype=jnp.uint32))
    return jnp.stack(planes)


def bitserial_expand_ref(a_codes, w_codes, abits: int, wbits: int):
    """Eq. (1) evaluated literally: Σ_p Σ_q 2^(p+q) · (plane_p(a) @ plane_q(w)).

    Validates that the plane decomposition itself is exact."""
    m, k = a_codes.shape
    _, n = w_codes.shape
    acc = jnp.zeros((m, n), jnp.int32)
    for p in range(abits):
        ap = (a_codes >> p) & 1
        for q in range(wbits):
            wq = (w_codes >> q) & 1
            acc = acc + (2 ** (p + q)) * jnp.matmul(ap, wq)
    return acc
