"""LSQ-style quantizers (L2, build-time only).

The integer semantics here mirror ``rust/src/quant`` exactly — the
coordinator's golden cross-check depends on both sides producing identical
codes:

* activations: unsigned ``n``-bit codes, ``a_real = s_a * a_u`` (zero-point 0,
  post-ReLU);
* weights: affine unsigned codes ``w_real = alpha * w_u + beta`` with
  ``alpha = s_w``, ``beta = -s_w * 2**(m-1)`` for ``m >= 2`` (offset binary)
  and ``alpha = 2 s_w``, ``beta = -s_w`` for binary weights;
* a quantized matmul/conv then decomposes as
  ``out = s_a * (alpha * ACC + beta * ASUM)`` with integer
  ``ACC = sum w_u a_u`` (the bit-serial kernel) and ``ASUM = sum a_u``.

LSQ [Esser et al., ICLR'20] learns the step sizes ``s_a, s_w`` by gradient
descent with a straight-through estimator and the 1/sqrt(Q·N) gradient scale;
``lsq_quantize`` implements that for ``train_lsq.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp


def round_ste(x):
    """Round with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


@functools.partial(jax.jit, static_argnames=("bits", "signed"))
def lsq_quantize(x, step, bits: int, signed: bool):
    """LSQ fake-quantization of `x` with learnable `step`.

    Returns the dequantized tensor; gradients flow to both `x` (STE) and
    `step` (LSQ's scaled gradient).
    """
    if signed:
        qn, qp = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        if bits == 1:
            # Binary weights {-s, +s}; straight-through gradient to x.
            g = 1.0 / math.sqrt(x.size)
            s = step * g + jax.lax.stop_gradient(step * (1.0 - g))
            sign = jnp.where(x >= 0, 1.0, -1.0)
            sign_ste = x + jax.lax.stop_gradient(sign - x)
            return s * sign_ste
    else:
        qn, qp = 0, 2**bits - 1
    grad_scale = 1.0 / math.sqrt(x.size * qp) if qp > 0 else 1.0
    s = step * grad_scale + jax.lax.stop_gradient(step * (1.0 - grad_scale))
    v = jnp.clip(x / s, qn, qp)
    return round_ste(v) * s


# ---------------------------------------------------------------------------
# Inference-side static quantizers (exact mirrors of rust/src/quant/lsq.rs).
# ---------------------------------------------------------------------------


def quantize_activations(a, bits: int):
    """Unsigned activation codes + scale. Mirrors `quantize_activations`."""
    maxv = jnp.maximum(jnp.max(a), 1e-8)
    qmax = 2**bits - 1
    scale = maxv / qmax
    # jnp.round implements round-half-to-even, like the Rust side.
    codes = jnp.clip(jnp.round(a / scale), 0, qmax).astype(jnp.int32)
    return codes, scale


def quantize_weights_unsigned(w, bits: int):
    """Affine unsigned weight codes. Mirrors `quantize_weights_unsigned`.

    Returns (codes int32, alpha, beta).
    """
    if bits == 1:
        s = jnp.maximum(jnp.mean(jnp.abs(w)), 1e-8)
        codes = (w >= 0).astype(jnp.int32)
        return codes, 2.0 * s, -s
    absmax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    qmax_side = 2 ** (bits - 1) - 1
    s = absmax / qmax_side
    offset = 2 ** (bits - 1)
    q = jnp.clip(jnp.round(w / s), -offset, qmax_side).astype(jnp.int32)
    return q + offset, s, -s * offset


def dequantize_weights(codes, alpha, beta):
    return alpha * codes.astype(jnp.float32) + beta


def requantize(acc, asum, act_scale, w_alpha, w_beta, bias, out_scale, out_bits: int):
    """Fig. 2's "Div/Mul + Clip + Round" (the scalar-FPU step on Quark).

    Mirrors `requantize_golden` in rust/src/quant/requant.rs.
    """
    alpha = act_scale * w_alpha / out_scale
    beta = act_scale * w_beta / out_scale
    t = alpha * acc.astype(jnp.float32) + beta * asum.astype(jnp.float32) + bias / out_scale
    qmax = 2**out_bits - 1
    return jnp.clip(jnp.round(t), 0, qmax).astype(jnp.int32)
