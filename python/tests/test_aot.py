"""AOT path tests: lowering produces valid HLO text and the lowered
computations agree with the oracles (via jax execution of the same jits)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.bitserial import qgemm
from compile.kernels.ref import qgemm_ref


def test_qgemm_hlo_text_shape():
    text = aot.lower_qgemm()
    assert text.startswith("HloModule"), text[:80]
    # Two int32 outputs in a tuple: (acc [8,16], asum [8]).
    assert "s32[8,16]" in text
    assert "s32[8]" in text


def test_qconv_hlo_text_shape():
    text = aot.lower_qconv()
    assert text.startswith("HloModule")
    assert "s32[256,64]" in text  # 16·16 output pixels × 64 channels


def test_qnet_hlo_text_shape():
    text = aot.lower_qnet()
    assert text.startswith("HloModule")
    assert "f32[10]" in text  # logits


def test_qgemm_artifact_semantics_match_ref():
    """The function that gets lowered is byte-for-byte the one tested here."""
    rng = np.random.default_rng(123)
    a = jnp.asarray(rng.integers(0, 4, (aot.QGEMM_M, aot.QGEMM_K)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 4, (aot.QGEMM_K, aot.QGEMM_N)), jnp.int32)
    acc, asum = qgemm(a, w, aot.QGEMM_BITS, aot.QGEMM_BITS)
    racc, rasum = qgemm_ref(a, w)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(racc))
    np.testing.assert_array_equal(np.asarray(asum), np.asarray(rasum))


def test_lowering_is_deterministic():
    assert aot.lower_qgemm() == aot.lower_qgemm()


def test_qnet_constants_are_baked():
    """The qnet artifact takes only the input tensor — weights are constants
    (Python must never be needed at serving time)."""
    net = model.make_qnet(seed=0)
    lowered = jax.jit(lambda x: (model.qnet_forward(net, x),)).lower(
        jax.ShapeDtypeStruct((16, 16, 64), jnp.int32)
    )
    # Exactly one parameter in the ENTRY computation (sub-computations of
    # fusions/reductions have their own parameters — ignore those).
    text = aot.to_hlo_text(lowered)
    entry = text[text.index("ENTRY") :]
    entry = entry[: entry.index("\n}")]
    assert "parameter(0)" in entry
    assert "parameter(1)" not in entry
