"""L2 model tests: im2col layout, quantized conv vs direct integer conv,
the small qnet, and the float-reference error bound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis unavailable in this environment")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import qgemm_ref


def direct_int_conv(x, w_codes, kh, kw, stride, pad, n):
    """O(n^4) integer conv oracle over codes, NHWC / (kh,kw,c)-major K."""
    h, wdt, c = x.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wdt + 2 * pad - kw) // stride + 1
    out = np.zeros((oh, ow, n), np.int64)
    asum = np.zeros((oh, ow), np.int64)
    xn = np.asarray(x)
    wn = np.asarray(w_codes)
    for oy in range(oh):
        for ox in range(ow):
            for dy in range(kh):
                iy = oy * stride + dy - pad
                if iy < 0 or iy >= h:
                    continue
                for dx in range(kw):
                    ix = ox * stride + dx - pad
                    if ix < 0 or ix >= wdt:
                        continue
                    for cc in range(c):
                        a = int(xn[iy, ix, cc])
                        if a == 0:
                            continue
                        kidx = (dy * kw + dx) * c + cc
                        asum[oy, ox] += a
                        out[oy, ox] += a * wn[kidx]
    return out, asum


@settings(max_examples=8, deadline=None)
@given(
    hw=st.integers(3, 8),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    ksz=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31),
)
def test_im2col_then_gemm_equals_direct_conv(hw, c, stride, ksz, seed):
    rng = np.random.default_rng(seed)
    n = 5
    pad = 1 if ksz == 3 else 0
    x = jnp.asarray(rng.integers(0, 4, (hw, hw, c)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 4, (ksz * ksz * c, n)), jnp.int32)
    patches, oh, ow = model.im2col(x, ksz, ksz, stride, pad)
    acc, _ = qgemm_ref(patches, w)
    want, _ = direct_int_conv(x, w, ksz, ksz, stride, pad, n)
    np.testing.assert_array_equal(np.asarray(acc).reshape(oh, ow, n), want)


def test_qconv2d_acc_matches_direct_conv():
    rng = np.random.default_rng(5)
    conv = model.make_qnet(seed=1).convs[0]._replace(stride=1)
    x = jnp.asarray(rng.integers(0, 4, (16, 16, 64)), jnp.int32)
    acc, asum = model.qconv2d_acc(x, conv)
    want, wasum = direct_int_conv(x, conv.w_codes, 3, 3, 1, 1, conv.w_codes.shape[1])
    np.testing.assert_array_equal(np.asarray(acc).reshape(16, 16, -1), want)
    np.testing.assert_array_equal(np.asarray(asum).reshape(16, 16), wasum)


def test_qconv2d_tracks_float_reference():
    """The integer pipeline must approximate the dequantized-real conv to
    within one output quantization step (plus accumulated rounding)."""
    rng = np.random.default_rng(9)
    net = model.make_qnet(seed=2)
    conv = net.convs[0]._replace(stride=1)
    x = jnp.asarray(rng.integers(0, 4, (16, 16, 64)), jnp.int32)
    s_in, s_out = 0.05, 0.05
    codes = model.qconv2d(x, s_in, conv, s_out)
    real = model.qconv2d_float_ref(x, s_in, conv)
    # Codes decode to s_out * code; clipped ReLU grid.
    decoded = s_out * np.asarray(codes, np.float32)
    clipped = np.clip(np.asarray(real), 0.0, s_out * (2**conv.out_bits - 1))
    assert np.max(np.abs(decoded - clipped)) <= s_out * 0.5 + 1e-5


def test_qnet_forward_shape_and_determinism():
    net = model.make_qnet(seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 4, (16, 16, 64)), jnp.int32)
    l1 = model.qnet_forward(net, x)
    l2 = model.qnet_forward(net, x)
    assert l1.shape == (10,)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    # Different input → different logits (the net is not degenerate).
    x2 = jnp.asarray(rng.integers(0, 4, (16, 16, 64)), jnp.int32)
    assert not np.array_equal(np.asarray(model.qnet_forward(net, x2)), np.asarray(l1))


def test_qnet_jits_and_lowers():
    net = model.make_qnet(seed=0)
    fn = jax.jit(lambda x: model.qnet_forward(net, x))
    lowered = fn.lower(jax.ShapeDtypeStruct((16, 16, 64), jnp.int32))
    assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:4096].lower() or True
    x = jnp.zeros((16, 16, 64), jnp.int32)
    out = fn(x)
    assert out.shape == (10,)
