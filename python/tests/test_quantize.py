"""Quantizer semantics — including exact agreement with the Rust side's
`rust/src/quant` (the cross-language contract the golden check rests on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis unavailable in this environment")
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    lsq_quantize,
    quantize_activations,
    quantize_weights_unsigned,
    requantize,
)


def test_binary_weights_are_sign_codes():
    # Mirror of rust quant::lsq::tests::binary_weights_are_sign_codes.
    w = jnp.asarray([0.5, -0.25, 0.75, -1.0], jnp.float32)
    codes, alpha, beta = quantize_weights_unsigned(w, 1)
    np.testing.assert_array_equal(np.asarray(codes), [1, 0, 1, 0])
    # ±s with s = mean |w| = 0.625 → alpha=1.25, beta=-0.625.
    assert abs(float(alpha) - 1.25) < 1e-6
    assert abs(float(beta) + 0.625) < 1e-6


def test_affine_identity_acc_asum():
    # Σ w_real·a_real == s_a·(α·ACC + β·ASUM) — mirror of the Rust test.
    w = jnp.asarray([0.4, -0.3, 0.9, -0.7], jnp.float32)
    a = jnp.asarray([0.2, 0.8, 0.5, 0.1], jnp.float32)
    wc, alpha, beta = quantize_weights_unsigned(w, 2)
    ac, s_a = quantize_activations(a, 2)
    acc = int(jnp.sum(wc * ac))
    asum = int(jnp.sum(ac))
    via_codes = float(s_a) * (float(alpha) * acc + float(beta) * asum)
    w_real = float(alpha) * np.asarray(wc, np.float32) + float(beta)
    a_real = float(s_a) * np.asarray(ac, np.float32)
    direct = float(np.sum(w_real * a_real))
    assert abs(via_codes - direct) < 1e-4


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 2**31))
def test_unsigned_weight_codes_bounded_and_close(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    codes, alpha, beta = quantize_weights_unsigned(w, bits)
    c = np.asarray(codes)
    assert c.min() >= 0 and c.max() <= 2**bits - 1
    deq = float(alpha) * c + float(beta)
    # Error bounded by one step.
    assert np.max(np.abs(deq - np.asarray(w))) <= float(alpha) * 0.5 + 1e-5


def test_activation_codes_unsigned_zero_point():
    a = jnp.asarray([0.0, 0.1, 0.5, 1.0, 2.0], jnp.float32)
    for bits in (1, 2, 8):
        codes, scale = quantize_activations(a, bits)
        c = np.asarray(codes)
        assert c[0] == 0
        assert c[-1] == 2**bits - 1
        assert c.min() >= 0


def test_requantize_matches_rust_examples():
    # Mirrors rust quant::requant tests (clamps_to_grid / asum_correction).
    acc = jnp.asarray([[-5, 2, 99]], jnp.int32)
    asum = jnp.zeros((1, 1), jnp.int32)
    out = requantize(acc, asum, 1.0, 1.0, 0.0, 0.0, 1.0, 2)
    np.testing.assert_array_equal(np.asarray(out)[0], [0, 2, 3])
    # alpha=1, beta=-0.5: ACC=10, ASUM=8 → 6.
    out = requantize(
        jnp.asarray([[10]], jnp.int32), jnp.asarray([[8]], jnp.int32), 1.0, 1.0, -0.5, 0.0, 1.0, 8
    )
    assert int(out[0, 0]) == 6


def test_requantize_rounds_half_to_even():
    out = requantize(
        jnp.asarray([[5, 7]], jnp.int32), jnp.zeros((1, 1), jnp.int32), 1.0, 0.5, 0.0, 0.0, 1.0, 8
    )
    np.testing.assert_array_equal(np.asarray(out)[0], [2, 4])


def test_lsq_gradients_flow_to_step_and_input():
    x = jnp.linspace(-1.0, 1.0, 32)
    for bits, signed in [(2, True), (2, False), (1, True), (8, False)]:
        def loss(step, x):
            return jnp.sum(lsq_quantize(x, step, bits, signed) ** 2)

        gs, gx = jax.grad(loss, argnums=(0, 1))(jnp.asarray(0.1), x)
        assert np.isfinite(float(gs)), f"step grad bits={bits}"
        assert np.all(np.isfinite(np.asarray(gx)))
        # STE: at least some input gradient is nonzero.
        assert np.any(np.abs(np.asarray(gx)) > 0)


def test_lsq_fp32_passthrough_limit():
    # With many bits, LSQ output approaches the input inside the clip range.
    x = jnp.linspace(-0.5, 0.5, 64)
    q = lsq_quantize(x, jnp.asarray(0.001), 8, True)
    assert float(jnp.max(jnp.abs(q - jnp.clip(x, -0.128, 0.127)))) < 1e-3
