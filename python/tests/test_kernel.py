"""L1 correctness: the Pallas bit-serial kernel vs the pure-jnp oracle.

Integer arithmetic → exact equality (`assert_array_equal`), with hypothesis
sweeping shapes and precisions (the pytest signal `make test` gates on).
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis unavailable in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels.bitserial import pack_rows, popcount32, qgemm, qgemm_bitserial
from compile.kernels.ref import bitserial_expand_ref, pack_planes_ref, qgemm_ref


def rand_codes(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2**bits, shape), jnp.int32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 200),
    n=st.integers(1, 80),
    abits=st.integers(1, 2),
    wbits=st.integers(1, 2),
    seed=st.integers(0, 2**31),
)
def test_qgemm_matches_ref_swept(m, k, n, abits, wbits, seed):
    rng = np.random.default_rng(seed)
    a = rand_codes(rng, (m, k), abits)
    w = rand_codes(rng, (k, n), wbits)
    acc, asum = qgemm(a, w, abits, wbits)
    racc, rasum = qgemm_ref(a, w)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(racc))
    np.testing.assert_array_equal(np.asarray(asum), np.asarray(rasum))


@settings(max_examples=10, deadline=None)
@given(
    k=st.integers(1, 300),
    bits=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_pack_rows_matches_ref(k, bits, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2**bits, (k,)), jnp.int32)
    ours = pack_rows(codes[None, :], bits)[:, 0, :]
    ref = pack_planes_ref(codes, bits)
    np.testing.assert_array_equal(np.asarray(ours), np.asarray(ref))


def test_popcount32_exhaustive_structure():
    rng = np.random.default_rng(7)
    xs = rng.integers(0, 2**32, 4096, dtype=np.uint32)
    got = np.asarray(popcount32(jnp.asarray(xs)))
    want = np.array([bin(int(x)).count("1") for x in xs], dtype=np.uint32)
    np.testing.assert_array_equal(got, want)


def test_popcount32_edge_values():
    xs = jnp.asarray([0, 1, 0xFFFFFFFF, 0x80000000, 0x55555555, 0xAAAAAAAA], jnp.uint32)
    got = np.asarray(popcount32(xs))
    np.testing.assert_array_equal(got, [0, 1, 32, 1, 16, 16])


def test_eq1_plane_decomposition_is_exact():
    """Paper Eq. (1): the plane-pair expansion equals the integer product."""
    rng = np.random.default_rng(3)
    for abits, wbits in [(1, 1), (1, 2), (2, 1), (2, 2)]:
        a = rand_codes(rng, (6, 77), abits)
        w = rand_codes(rng, (77, 13), wbits)
        np.testing.assert_array_equal(
            np.asarray(bitserial_expand_ref(a, w, abits, wbits)),
            np.asarray(qgemm_ref(a, w)[0]),
        )


@pytest.mark.parametrize("bm,bn", [(1, 1), (4, 16), (8, 64), (16, 128)])
def test_tile_size_independence(bm, bn):
    """The BlockSpec tiling must not change the numbers."""
    rng = np.random.default_rng(11)
    a = rand_codes(rng, (10, 96), 2)
    w = rand_codes(rng, (96, 33), 2)
    base = np.asarray(qgemm_ref(a, w)[0])
    got = np.asarray(qgemm_bitserial(a, w, 2, 2, bm=bm, bn=bn))
    np.testing.assert_array_equal(got, base)


def test_max_code_values_no_overflow():
    """All-max codes at the paper's largest layer K: accumulators stay exact
    (K=4608 × 3 × 3 = 41472 ≪ 2^31)."""
    k = 4608
    a = jnp.full((2, k), 3, jnp.int32)
    w = jnp.full((k, 8), 3, jnp.int32)
    acc, asum = qgemm(a, w, 2, 2)
    assert int(acc[0, 0]) == 9 * k
    assert int(asum[0]) == 3 * k
